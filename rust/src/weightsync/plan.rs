//! Resharding planner: the minimal per-link transfer schedule between two
//! [`Layout`]s.
//!
//! Every element of the flat vector is owned by exactly one source rank and
//! exactly one destination rank, so the minimal schedule is the set of
//! non-empty intersections of source and destination intervals: each
//! intersection becomes one [`TransferOp`] on the link `src -> dst`, and no
//! element ever moves twice. Ops on distinct links run in parallel on the
//! cluster (each GPU pushes only its own shard over its own link), so the
//! modelled DDMA time is the *max* over links, not the sum — the paper's
//! linear-scalability property falls straight out of this schedule.
//!
//! The sweep is O(|src shards| + |dst shards|): both interval lists are
//! sorted covers of the same range, so a two-pointer walk visits every
//! overlap exactly once.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};
use crate::weightsync::layout::Layout;

/// Move `[start, start+len)` from source rank `src` to destination rank
/// `dst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferOp {
    pub src: usize,
    pub dst: usize,
    pub start: usize,
    pub len: usize,
}

impl TransferOp {
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// The full schedule for one publish: ops sorted by `start`, tiling
/// `[0, num_params)` exactly once.
#[derive(Debug, Clone)]
pub struct ReshardPlan {
    pub n_src: usize,
    pub n_dst: usize,
    pub num_params: usize,
    pub ops: Vec<TransferOp>,
}

impl ReshardPlan {
    /// Elements moved per (src, dst) link.
    pub fn link_elems(&self) -> BTreeMap<(usize, usize), usize> {
        let mut out = BTreeMap::new();
        for op in &self.ops {
            *out.entry((op.src, op.dst)).or_insert(0) += op.len;
        }
        out
    }

    /// Ops per (src, dst) link (per-tensor launches the schedule issues).
    pub fn link_ops(&self) -> BTreeMap<(usize, usize), usize> {
        let mut out = BTreeMap::new();
        for op in &self.ops {
            *out.entry((op.src, op.dst)).or_insert(0) += 1;
        }
        out
    }

    /// The busiest link's element count — with links in parallel, transfer
    /// time scales with this, not with `num_params`.
    pub fn max_link_elems(&self) -> usize {
        self.link_elems().values().copied().max().unwrap_or(0)
    }

    pub fn total_elems(&self) -> usize {
        self.ops.iter().map(|o| o.len).sum()
    }

    /// Number of active (src, dst) links.
    pub fn n_links(&self) -> usize {
        self.link_elems().len()
    }

    /// Partition the schedule into `n_groups` link-groups for the
    /// background streaming executor (one worker thread per group). Ops are
    /// grouped by destination rank modulo the group count, so every packet
    /// bound for one generator rank flows through one worker — the testbed
    /// analogue of one transfer thread per NVLink/IB link — and groups stay
    /// element-balanced for balanced destination layouts. `n_groups` is
    /// clamped to [1, n_dst]; empty groups are dropped.
    ///
    /// This is the `sync_link_groups = 0` (auto) behaviour; an explicit
    /// group count routes through the bandwidth-aware
    /// [`ReshardPlan::link_groups_balanced`] instead.
    pub fn link_groups(&self, n_groups: usize) -> Vec<Vec<TransferOp>> {
        let n = n_groups.clamp(1, self.n_dst.max(1));
        let mut groups: Vec<Vec<TransferOp>> = vec![Vec::new(); n];
        for &op in &self.ops {
            groups[op.dst % n].push(op);
        }
        groups.retain(|g| !g.is_empty());
        if groups.is_empty() {
            groups.push(Vec::new()); // degenerate empty plan: one idle group
        }
        groups
    }

    /// Bandwidth-aware link-group partition: destination ranks are weighed
    /// by their cumulative [`ReshardPlan::link_elems`] volume and assigned
    /// greedy largest-first to the currently lightest group (LPT
    /// scheduling), so worker streams stay element-balanced even when the
    /// destination layout is skewed — rank-modulo grouping can put every
    /// heavy rank in the same group and leave other workers idle. Like
    /// [`ReshardPlan::link_groups`], a destination rank's ops never split
    /// across groups (one stream per receiver), and ops keep their plan
    /// order within a group.
    pub fn link_groups_balanced(&self, n_groups: usize) -> Vec<Vec<TransferOp>> {
        let n = n_groups.clamp(1, self.n_dst.max(1));
        // cumulative elements per destination rank
        let mut per_dst: BTreeMap<usize, usize> = BTreeMap::new();
        for op in &self.ops {
            *per_dst.entry(op.dst).or_insert(0) += op.len;
        }
        // largest destination first onto the lightest group
        let mut dsts: Vec<(usize, usize)> = per_dst.into_iter().collect();
        dsts.sort_by_key(|(dst, elems)| (std::cmp::Reverse(*elems), *dst));
        let mut load = vec![0usize; n];
        let mut home: BTreeMap<usize, usize> = BTreeMap::new();
        for (dst, elems) in dsts {
            let g = (0..n).min_by_key(|g| load[*g]).unwrap();
            load[g] += elems;
            home.insert(dst, g);
        }
        let mut groups: Vec<Vec<TransferOp>> = vec![Vec::new(); n];
        for &op in &self.ops {
            groups[home[&op.dst]].push(op);
        }
        groups.retain(|g| !g.is_empty());
        if groups.is_empty() {
            groups.push(Vec::new()); // degenerate empty plan: one idle group
        }
        groups
    }
}

/// Max-over-min element load of a grouping (1.0 = perfectly balanced);
/// groups moving zero elements count as empty and make the ratio infinite.
pub fn group_balance_ratio(groups: &[Vec<TransferOp>]) -> f64 {
    let loads: Vec<usize> = groups
        .iter()
        .map(|g| g.iter().map(|o| o.len).sum())
        .collect();
    let max = loads.iter().copied().max().unwrap_or(0) as f64;
    let min = loads.iter().copied().min().unwrap_or(0) as f64;
    if min == 0.0 {
        if max == 0.0 {
            1.0
        } else {
            f64::INFINITY
        }
    } else {
        max / min
    }
}

/// Compute the minimal transfer schedule from `src` to `dst`.
pub fn plan_reshard(src: &Layout, dst: &Layout) -> Result<ReshardPlan> {
    if src.num_params != dst.num_params {
        return Err(Error::Config(format!(
            "reshard layouts disagree on size: src {} vs dst {}",
            src.num_params, dst.num_params
        )));
    }
    src.validate()?;
    dst.validate()?;
    let mut ops = Vec::with_capacity(src.shards.len() + dst.shards.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < src.shards.len() && j < dst.shards.len() {
        let a = &src.shards[i];
        let b = &dst.shards[j];
        let start = a.start.max(b.start);
        let end = a.end().min(b.end());
        if end > start {
            ops.push(TransferOp {
                src: a.rank,
                dst: b.rank,
                start,
                len: end - start,
            });
        }
        // advance whichever interval finishes first
        if a.end() <= b.end() {
            i += 1;
        } else {
            j += 1;
        }
    }
    Ok(ReshardPlan {
        n_src: src.n_ranks,
        n_dst: dst.n_ranks,
        num_params: src.num_params,
        ops,
    })
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::weightsync::layout::contiguous_entries;

    fn assert_exact_tiling(plan: &ReshardPlan) {
        assert_eq!(plan.total_elems(), plan.num_params);
        let mut at = 0usize;
        for op in &plan.ops {
            assert_eq!(op.start, at, "ops must tile without gap/overlap");
            at = op.end();
        }
        assert_eq!(at, plan.num_params);
    }

    #[test]
    fn identical_layouts_are_local_copies() {
        let l = Layout::fsdp(100, 4);
        let p = plan_reshard(&l, &l).unwrap();
        assert_exact_tiling(&p);
        assert_eq!(p.ops.len(), 4);
        assert!(p.ops.iter().all(|o| o.src == o.dst));
    }

    #[test]
    fn fsdp_to_tp_crosses_links() {
        let es = contiguous_entries(&[40, 40, 20]);
        let src = Layout::fsdp(100, 4);
        let dst = Layout::tp(100, 2, &es).unwrap();
        let p = plan_reshard(&src, &dst).unwrap();
        assert_exact_tiling(&p);
        // per-tensor TP vs contiguous FSDP must produce cross-rank traffic
        assert!(p.ops.iter().any(|o| o.src != o.dst));
        assert!(p.n_links() > 2);
    }

    #[test]
    fn max_link_below_total() {
        let src = Layout::fsdp(1000, 8);
        let dst = Layout::tp_flat(1000, 4);
        let p = plan_reshard(&src, &dst).unwrap();
        assert_exact_tiling(&p);
        assert!(p.max_link_elems() < p.total_elems());
        // contiguous->contiguous with 8->4 ranks: exactly one op per src shard
        assert_eq!(p.ops.len(), 8);
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(plan_reshard(&Layout::fsdp(10, 2), &Layout::fsdp(12, 2)).is_err());
    }

    #[test]
    fn balanced_groups_beat_rank_modulo_on_skewed_layouts() {
        use crate::weightsync::layout::{LayoutKind, ShardInterval};
        // destination ranks with very uneven volumes: 700 / 50 / 200 / 50
        let dst = Layout {
            kind: LayoutKind::Tp,
            n_ranks: 4,
            num_params: 1000,
            shards: vec![
                ShardInterval { rank: 0, start: 0, len: 700 },
                ShardInterval { rank: 1, start: 700, len: 50 },
                ShardInterval { rank: 2, start: 750, len: 200 },
                ShardInterval { rank: 3, start: 950, len: 50 },
            ],
        };
        let p = plan_reshard(&Layout::fsdp(1000, 4), &dst).unwrap();
        for n in [2usize, 3] {
            let modulo = p.link_groups(n);
            let balanced = p.link_groups_balanced(n);
            // both are exact partitions that never split a destination
            for groups in [&modulo, &balanced] {
                let total: usize = groups.iter().map(|g| g.len()).sum();
                assert_eq!(total, p.ops.len());
                let mut home: BTreeMap<usize, usize> = BTreeMap::new();
                for (gi, g) in groups.iter().enumerate() {
                    for op in g {
                        assert_eq!(*home.entry(op.dst).or_insert(gi), gi);
                    }
                }
            }
            let r_mod = group_balance_ratio(&modulo);
            let r_bal = group_balance_ratio(&balanced);
            assert!(
                r_bal <= r_mod,
                "n={n}: balanced ratio {r_bal} worse than modulo {r_mod}"
            );
        }
        // the 2-group case is where modulo hurts most: ranks 0+2 share a
        // worker (900 elems) while 1+3 (100) idles — LPT must do strictly
        // better
        let r_mod = group_balance_ratio(&p.link_groups(2));
        let r_bal = group_balance_ratio(&p.link_groups_balanced(2));
        assert!(r_bal < r_mod, "balanced {r_bal} !< modulo {r_mod}");
        assert!(r_bal <= 3.0, "700/300 split expected, got {r_bal}");
    }

    #[test]
    fn link_groups_partition_ops_exactly() {
        let src = Layout::fsdp(1000, 8);
        let dst = Layout::tp_flat(1000, 4);
        let p = plan_reshard(&src, &dst).unwrap();
        for n in [1usize, 2, 3, 4, 99] {
            let groups = p.link_groups(n);
            assert!(groups.len() <= n.clamp(1, 4));
            let total: usize = groups.iter().map(|g| g.len()).sum();
            assert_eq!(total, p.ops.len(), "groups must tile the schedule");
            // a destination rank's ops never split across groups
            let mut home: BTreeMap<usize, usize> = BTreeMap::new();
            for (gi, g) in groups.iter().enumerate() {
                for op in g {
                    assert_eq!(*home.entry(op.dst).or_insert(gi), gi);
                }
            }
        }
    }
}
