//! Per-shard versioned transfer with quantized and delta encodings.
//!
//! Each [`TransferOp`] of a [`ReshardPlan`] becomes one [`ShardPacket`]: the
//! source rank encodes its interval, the destination rank applies it into
//! its receive buffer. Packets carry the weight version so receivers can
//! fence: a packet for any version other than the one currently staging is
//! dropped, never mixed.
//!
//! Four wire encodings ([`ShardEncoding`]):
//!
//! * `F32` — 4 bytes/elem passthrough, bit-exact.
//! * `Int8` — symmetric per-shard quantization reusing `model::quant`,
//!   dequantized at attach, error within [`crate::model::int8_error_bound`].
//! * `Delta` — encoded against a *base* version (the previously published
//!   snapshot). Changed elements (bitwise `f32::to_bits` comparison) ship as
//!   sparse index+value pairs when sparse enough; past the sparse
//!   break-even the XOR word stream is zero-run-length encoded
//!   ([`ShardPayload::RleDelta`]: `(zero_run, literal_count, literals…)`
//!   token groups — clustered updates compress to their literal words
//!   while the untouched regions collapse to a single counter), falling
//!   back to the raw dense XOR only when RLE would not be smaller. All
//!   three reconstruct **bit-exactly** — the XOR forms by construction, the
//!   sparse form because unchanged elements are, by definition of the
//!   changed set, already identical in the base. Delta payloads carry
//!   `base_version`; a receiver whose staging buffer was not seeded from
//!   exactly that version must reject the packet (the *base-version
//!   fence*, enforced by [`crate::weightsync::GeneratorSlot::recv`]) and
//!   be re-sent the shard as full f32.
//! * `TopK` — sparse delta capped at the k largest-magnitude changes per
//!   shard; dropped changes keep their base value, so the reconstruction
//!   error is bounded by the largest dropped |update| (returned by
//!   [`encode_shard_delta`] and accumulated into
//!   [`TransferTiming::err_bound`]). Falls back to full f32 when the sparse
//!   packing would be denser than the break-even threshold.
//!
//! Timing: each op is timed individually. On the cluster all links move in
//! parallel, so the modelled DDMA time for a publish is
//! [`TransferTiming::max_shard_secs`], while the single-core testbed pays
//! [`TransferTiming::total_secs`].

use std::time::Instant;

use crate::model::{quantize_int8, QuantizedParams};
use crate::runtime::ParamEntry;
use crate::weightsync::plan::{ReshardPlan, TransferOp};

/// Sparse index+value packing costs 8 bytes/changed elem vs 4 bytes/elem
/// dense, so past half density a sparse packet is pure overhead.
pub const SPARSE_BREAK_EVEN_DENSITY: f64 = 0.5;

/// Below this density the sparse packing is at worst 2 bytes per element
/// of the op and scattered updates dominate, so the exact encoder skips
/// computing the XOR/RLE candidates (an extra O(len) pass that clustered
/// updates would need to amortize); at or above it, the smallest of
/// sparse / RLE / dense wins.
pub const RLE_CANDIDATE_DENSITY: f64 = 0.25;

/// Wire encoding for shard payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardEncoding {
    /// 4 bytes/elem, bit-exact
    F32,
    /// 1 byte/elem + one f32 scale per shard; the paper's fp8-generator
    /// analogue — the attached weights are a quantized snapshot of pi
    Int8,
    /// exact delta vs the previous published version: sparse index+value
    /// when sparse enough, dense bitwise-XOR otherwise (both bit-exact,
    /// base-version fenced)
    Delta,
    /// top-k sparse delta: only the k largest |updates| ship; bounded error,
    /// base-version fenced, full-f32 fallback past the density threshold
    TopK,
    /// adaptive per-publish selection: measure the update density against
    /// the base at encode time and pick exact delta (smallest of
    /// sparse/RLE/dense) below [`SPARSE_BREAK_EVEN_DENSITY`], full f32 at
    /// or above it — a dense update gains nothing from the delta machinery
    /// and the full form needs no base fence
    Auto,
}

impl ShardEncoding {
    /// Delta-family encodings need a base snapshot and the base-version
    /// fence on the receive side. `Auto` is included: it *may* ship deltas,
    /// so receivers must seed staging from their front and keep the fence
    /// armed (full-f32 payloads apply fine on a delta-seeded staging).
    pub fn is_delta(self) -> bool {
        matches!(
            self,
            ShardEncoding::Delta | ShardEncoding::TopK | ShardEncoding::Auto
        )
    }
}

/// One encoded shard in flight.
#[derive(Debug, Clone)]
pub struct ShardPacket {
    pub version: u64,
    pub op: TransferOp,
    pub payload: ShardPayload,
}

#[derive(Debug, Clone)]
pub enum ShardPayload {
    F32(Vec<f32>),
    Int8(QuantizedParams),
    /// changed elements only, as (index within the op, new value) pairs;
    /// valid only on a buffer holding `base_version`'s content
    SparseDelta {
        base_version: u64,
        idx: Vec<u32>,
        val: Vec<f32>,
    },
    /// bitwise XOR of the op's interval vs `base_version`; applying it to
    /// exactly that base reproduces the new bits verbatim
    DenseDelta { base_version: u64, xor: Vec<u32> },
    /// zero-run-length-encoded XOR stream: repeated `(zero_run,
    /// literal_count, literals…)` token groups over the op's XOR words.
    /// Chosen over [`ShardPayload::DenseDelta`] whenever it is smaller
    /// (clustered updates); identical reconstruction guarantees
    RleDelta { base_version: u64, runs: Vec<u32> },
}

/// Zero-run encode an XOR word stream into `(zero_run, literal_count,
/// literals…)` token groups. Unchanged (all-zero) stretches collapse to a
/// single counter; a trailing all-zero stretch encodes as `(n, 0)`.
pub fn rle_encode_xor(xor: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < xor.len() {
        let z0 = i;
        while i < xor.len() && xor[i] == 0 {
            i += 1;
        }
        let lit0 = i;
        while i < xor.len() && xor[i] != 0 {
            i += 1;
        }
        out.push((lit0 - z0) as u32);
        out.push((i - lit0) as u32);
        out.extend_from_slice(&xor[lit0..i]);
    }
    out
}

impl ShardPacket {
    /// Bytes on the wire (payload only; the op header is negligible).
    pub fn payload_bytes(&self) -> usize {
        match &self.payload {
            ShardPayload::F32(v) => v.len() * 4,
            ShardPayload::Int8(q) => q.data.len() + q.scales.len() * 4,
            ShardPayload::SparseDelta { idx, val, .. } => idx.len() * 4 + val.len() * 4,
            ShardPayload::DenseDelta { xor, .. } => xor.len() * 4,
            ShardPayload::RleDelta { runs, .. } => runs.len() * 4,
        }
    }

    /// The base version a delta payload must land on (None for
    /// self-contained payloads).
    pub fn base_version(&self) -> Option<u64> {
        match &self.payload {
            ShardPayload::SparseDelta { base_version, .. }
            | ShardPayload::DenseDelta { base_version, .. }
            | ShardPayload::RleDelta { base_version, .. } => Some(*base_version),
            _ => None,
        }
    }
}

/// A shard viewed as a single-tensor layout, so the per-tensor quantizer in
/// `model::quant` applies per-shard unchanged.
fn shard_entry(len: usize) -> [ParamEntry; 1] {
    [ParamEntry {
        name: "shard".into(),
        shape: vec![len],
        offset: 0,
    }]
}

/// Encode one op's interval of `params` (the source rank's push). The
/// delta-family encodings need a base snapshot — without one they degrade
/// to full f32 (which is also the fallback a receiver's base-version fence
/// triggers), so this stays total over the enum.
pub fn encode_shard(
    params: &[f32],
    version: u64,
    op: TransferOp,
    encoding: ShardEncoding,
) -> ShardPacket {
    let chunk = &params[op.start..op.end()];
    let payload = match encoding {
        ShardEncoding::F32
        | ShardEncoding::Delta
        | ShardEncoding::TopK
        | ShardEncoding::Auto => ShardPayload::F32(chunk.to_vec()),
        ShardEncoding::Int8 => {
            ShardPayload::Int8(quantize_int8(chunk, &shard_entry(chunk.len())))
        }
    };
    ShardPacket {
        version,
        op,
        payload,
    }
}

/// |new - base| used to rank top-k candidates; a bit-level change whose
/// arithmetic difference is NaN (NaN appeared or disappeared) must always
/// be kept, so it ranks as infinite.
fn update_magnitude(new: f32, base: f32) -> f32 {
    let d = (new - base).abs();
    if d.is_nan() {
        f32::INFINITY
    } else {
        d
    }
}

/// Encode one op's interval as a delta against `base` (the previously
/// published snapshot, version `base_version`).
///
/// * `topk` None — exact: every changed element ships (sparse pairs under
///   [`SPARSE_BREAK_EVEN_DENSITY`], dense XOR above it). Returned bound 0.
/// * `topk` Some(k) — at most the k largest-|update| changes ship; returns
///   the largest *dropped* |update|, which bounds the reconstruction error
///   of this shard. Falls back to full f32 (bound 0) when even the capped
///   packing is denser than break-even.
pub fn encode_shard_delta(
    params: &[f32],
    base: &[f32],
    base_version: u64,
    version: u64,
    op: TransferOp,
    topk: Option<usize>,
) -> (ShardPacket, f32) {
    let chunk = &params[op.start..op.end()];
    let base_chunk = &base[op.start..op.end()];
    // bitwise comparison: catches sign-of-zero and NaN-payload changes that
    // `==` would miss, which is what makes sparse reconstruction bit-exact
    let mut changed: Vec<(u32, f32, f32)> = chunk
        .iter()
        .zip(base_chunk)
        .enumerate()
        .filter(|(_, (n, b))| n.to_bits() != b.to_bits())
        .map(|(i, (n, b))| (i as u32, *n, update_magnitude(*n, *b)))
        .collect();

    let mut dropped_bound = 0.0f32;
    if let Some(k) = topk {
        let k = k.max(1);
        if changed.len() > k {
            changed.sort_unstable_by(|a, b| b.2.total_cmp(&a.2));
            dropped_bound = changed[k].2;
            changed.truncate(k);
            changed.sort_unstable_by_key(|c| c.0);
        }
    }

    // 8 bytes per sparse pair vs 4 per dense elem: sparse wins while the
    // changed density stays under SPARSE_BREAK_EVEN_DENSITY
    let density = changed.len() as f64 / op.len.max(1) as f64;
    let sparse = |changed: &[(u32, f32, f32)]| ShardPayload::SparseDelta {
        base_version,
        idx: changed.iter().map(|c| c.0).collect(),
        val: changed.iter().map(|c| c.1).collect(),
    };
    let payload = if topk.is_some() {
        if density < SPARSE_BREAK_EVEN_DENSITY {
            sparse(&changed)
        } else {
            // top-k past break-even: the delta machinery buys nothing,
            // ship the shard whole (exact, no base fence needed)
            dropped_bound = 0.0;
            ShardPayload::F32(chunk.to_vec())
        }
    } else if density < RLE_CANDIDATE_DENSITY {
        sparse(&changed)
    } else {
        // exact mode at moderate-to-high density: smallest of sparse / RLE
        // / dense, all bit-exact. Clustered updates make the zero-run
        // encoding win well below the sparse break-even (one run of
        // literals + two counters per gap); scattered ones keep sparse or,
        // past break-even, raw dense XOR.
        let xor: Vec<u32> = chunk
            .iter()
            .zip(base_chunk)
            .map(|(n, b)| n.to_bits() ^ b.to_bits())
            .collect();
        let runs = rle_encode_xor(&xor);
        let sparse_words = 2 * changed.len();
        if sparse_words <= runs.len().min(xor.len()) {
            sparse(&changed)
        } else if runs.len() < xor.len() {
            ShardPayload::RleDelta { base_version, runs }
        } else {
            ShardPayload::DenseDelta { base_version, xor }
        }
    };
    (
        ShardPacket {
            version,
            op,
            payload,
        },
        dropped_bound,
    )
}

/// Adaptive per-publish encoding ([`ShardEncoding::Auto`]): measure the
/// op's bitwise update density against `base` and pick the wire form at
/// encode time — exact delta (the usual smallest-of sparse/RLE/dense
/// selection of [`encode_shard_delta`]) below
/// [`SPARSE_BREAK_EVEN_DENSITY`], full f32 at or above it. Both forms are
/// bit-exact; the full form is additionally self-contained (no base fence,
/// so a receiver whose staging lost the base needs no re-send). Returns
/// the packet plus the measured density, which the sync plane accumulates
/// into its telemetry (`BENCH_weightsync.json` density row).
pub fn encode_shard_auto(
    params: &[f32],
    base: &[f32],
    base_version: u64,
    version: u64,
    op: TransferOp,
) -> (ShardPacket, f64) {
    let chunk = &params[op.start..op.end()];
    let base_chunk = &base[op.start..op.end()];
    let changed = chunk
        .iter()
        .zip(base_chunk)
        .filter(|(n, b)| n.to_bits() != b.to_bits())
        .count();
    let density = changed as f64 / op.len.max(1) as f64;
    let pkt = if density >= SPARSE_BREAK_EVEN_DENSITY {
        encode_shard(params, version, op, ShardEncoding::F32)
    } else {
        encode_shard_delta(params, base, base_version, version, op, None).0
    };
    (pkt, density)
}

/// Apply a packet into the receive buffer (the destination rank's attach);
/// int8 payloads dequantize here, straight into `dst` — this is the publish
/// fan-out hot path (one call per op per subscriber), so no intermediate
/// allocation.
pub fn apply_packet(dst: &mut [f32], pkt: &ShardPacket) {
    let range = pkt.op.start..pkt.op.end();
    match &pkt.payload {
        ShardPayload::F32(v) => dst[range].copy_from_slice(v),
        ShardPayload::Int8(q) => {
            // same math as model::dequantize_int8 (one tensor, one scale),
            // written in place
            let scale = q.scales.first().copied().unwrap_or(1.0);
            for (out, x) in dst[range].iter_mut().zip(&q.data) {
                *out = *x as f32 * scale;
            }
        }
        // Delta payloads assume dst currently holds the base version's
        // content over this interval — the base-version fence
        // (GeneratorSlot::recv) guarantees it on the streaming path; direct
        // callers (run_transfer_delta, tests) must seed dst themselves.
        ShardPayload::SparseDelta { idx, val, .. } => {
            for (i, v) in idx.iter().zip(val) {
                dst[pkt.op.start + *i as usize] = *v;
            }
        }
        ShardPayload::DenseDelta { xor, .. } => {
            for (out, x) in dst[range].iter_mut().zip(xor) {
                *out = f32::from_bits(out.to_bits() ^ *x);
            }
        }
        ShardPayload::RleDelta { runs, .. } => {
            // walk the token groups; skipping a zero run IS applying it
            // (XOR with 0 is the identity)
            let mut at = pkt.op.start;
            let mut i = 0;
            while i + 1 < runs.len() {
                at += runs[i] as usize;
                let lits = runs[i + 1] as usize;
                for k in 0..lits {
                    let x = runs[i + 2 + k];
                    dst[at + k] = f32::from_bits(dst[at + k].to_bits() ^ x);
                }
                at += lits;
                i += 2 + lits;
            }
            debug_assert!(at <= pkt.op.end());
        }
    }
}

/// Timing + fidelity record for one executed plan.
#[derive(Debug, Clone, Default)]
pub struct TransferTiming {
    /// encode+apply seconds per op, in plan order
    pub shard_secs: Vec<f64>,
    /// payload bytes moved
    pub bytes: usize,
    /// max |dst - src| over quantized ops (0.0 for pure-f32 plans)
    pub max_abs_err: f32,
    /// the worst-case bound the quantizer guarantees for this data
    /// (see [`crate::model::int8_error_bound`]); `max_abs_err <= err_bound`
    /// always holds
    pub err_bound: f32,
}

impl TransferTiming {
    /// Modelled cluster DDMA time: all links move in parallel, publish
    /// completes when the slowest shard lands.
    pub fn max_shard_secs(&self) -> f64 {
        self.shard_secs.iter().cloned().fold(0.0, f64::max)
    }

    /// Single-core testbed time (shards moved sequentially).
    pub fn total_secs(&self) -> f64 {
        self.shard_secs.iter().sum()
    }
}

/// Execute a full plan `src -> dst` at `version`: encode each op, apply it,
/// time it, and (for quantized plans) measure the realized round-trip error
/// against its bound.
pub fn run_transfer(
    params: &[f32],
    dst: &mut [f32],
    plan: &ReshardPlan,
    version: u64,
    encoding: ShardEncoding,
) -> TransferTiming {
    assert_eq!(params.len(), plan.num_params);
    assert_eq!(dst.len(), plan.num_params);
    let mut timing = TransferTiming::default();
    for &op in &plan.ops {
        let t0 = Instant::now();
        let pkt = encode_shard(params, version, op, encoding);
        timing.bytes += pkt.payload_bytes();
        apply_packet(dst, &pkt);
        timing.shard_secs.push(t0.elapsed().as_secs_f64());
        if encoding == ShardEncoding::Int8 {
            let src_chunk = &params[op.start..op.end()];
            let maxabs = src_chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            timing.err_bound = timing.err_bound.max(crate::model::int8_error_bound(maxabs));
            for (a, b) in src_chunk.iter().zip(&dst[op.start..op.end()]) {
                timing.max_abs_err = timing.max_abs_err.max((a - b).abs());
            }
        }
    }
    timing
}

/// Execute a full delta-encoded plan at `version` against `base`
/// (`base_version`'s snapshot). `dst` must hold the base content on entry —
/// the testbed analogue of the receiver's staging buffer seeded from its
/// front — and holds the reconstruction on exit. `topk_frac` Some(f) caps
/// each shard at ceil(f * len) updates; None is the exact Delta encoding.
///
/// `err_bound` is the largest dropped |update| across shards (0 for exact
/// delta) and `max_abs_err` the realized reconstruction error, measured
/// bitwise-aware: exact-delta plans always report 0.
pub fn run_transfer_delta(
    params: &[f32],
    base: &[f32],
    dst: &mut [f32],
    plan: &ReshardPlan,
    base_version: u64,
    version: u64,
    topk_frac: Option<f64>,
) -> TransferTiming {
    assert_eq!(params.len(), plan.num_params);
    assert_eq!(base.len(), plan.num_params);
    assert_eq!(dst.len(), plan.num_params);
    let mut timing = TransferTiming::default();
    for &op in &plan.ops {
        let t0 = Instant::now();
        let k = topk_frac.map(|f| ((op.len as f64 * f).ceil() as usize).max(1));
        let (pkt, bound) = encode_shard_delta(params, base, base_version, version, op, k);
        timing.bytes += pkt.payload_bytes();
        apply_packet(dst, &pkt);
        timing.shard_secs.push(t0.elapsed().as_secs_f64());
        timing.err_bound = timing.err_bound.max(bound);
        for (a, b) in params[op.start..op.end()]
            .iter()
            .zip(&dst[op.start..op.end()])
        {
            if a.to_bits() != b.to_bits() {
                timing.max_abs_err = timing.max_abs_err.max(update_magnitude(*a, *b));
            }
        }
    }
    timing
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weightsync::layout::Layout;
    use crate::weightsync::plan::plan_reshard;

    fn params(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.37).sin() * 3.0).collect()
    }

    #[test]
    fn f32_transfer_is_exact() {
        let src = params(503);
        let plan =
            plan_reshard(&Layout::fsdp(503, 5), &Layout::tp_flat(503, 3)).unwrap();
        let mut dst = vec![0.0f32; 503];
        let t = run_transfer(&src, &mut dst, &plan, 1, ShardEncoding::F32);
        assert_eq!(dst, src);
        assert_eq!(t.bytes, 503 * 4);
        assert_eq!(t.max_abs_err, 0.0);
        assert_eq!(t.shard_secs.len(), plan.ops.len());
    }

    #[test]
    fn int8_transfer_within_bound_and_smaller() {
        let src = params(1024);
        let plan =
            plan_reshard(&Layout::fsdp(1024, 4), &Layout::tp_flat(1024, 4)).unwrap();
        let mut dst = vec![0.0f32; 1024];
        let t = run_transfer(&src, &mut dst, &plan, 1, ShardEncoding::Int8);
        assert!(t.max_abs_err > 0.0, "int8 roundtrip should not be exact");
        assert!(
            t.max_abs_err <= t.err_bound,
            "err {} > bound {}",
            t.max_abs_err,
            t.err_bound
        );
        // ~1 byte/elem + 4-byte scale per shard vs 4 bytes/elem
        assert!(t.bytes < 1024 * 2);
    }

    #[test]
    fn inplace_dequant_matches_model_dequantize() {
        // apply_packet's in-place int8 arm must agree bit-for-bit with the
        // reference model::dequantize_int8 it replaces on the hot path.
        let src = params(37);
        let op = TransferOp {
            src: 0,
            dst: 0,
            start: 5,
            len: 32,
        };
        let pkt = encode_shard(&src, 1, op, ShardEncoding::Int8);
        let mut dst = vec![0.0f32; 37];
        apply_packet(&mut dst, &pkt);
        let ShardPayload::Int8(q) = &pkt.payload else {
            panic!("int8 packet expected")
        };
        let reference = crate::model::dequantize_int8(q, &shard_entry(q.data.len()));
        assert_eq!(&dst[5..37], &reference[..]);
        // outside the op's interval stays untouched
        assert!(dst[..5].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn sparse_delta_roundtrips_bit_exactly() {
        let base = params(512);
        let mut new = base.clone();
        // ~3% of elements change, including a sign-of-zero flip
        for i in (0..512).step_by(37) {
            new[i] += 0.125;
        }
        new[1] = -0.0;
        let plan =
            plan_reshard(&Layout::fsdp(512, 4), &Layout::tp_flat(512, 2)).unwrap();
        let mut dst = base.clone();
        let t = run_transfer_delta(&new, &base, &mut dst, &plan, 1, 2, None);
        assert!(
            dst.iter().zip(&new).all(|(a, b)| a.to_bits() == b.to_bits()),
            "exact delta must reconstruct bit-exactly"
        );
        assert_eq!(t.max_abs_err, 0.0);
        assert_eq!(t.err_bound, 0.0);
        // sparse packing: far fewer bytes than the 512*4 full transfer
        assert!(t.bytes < 512 * 4 / 2, "delta bytes {} not sparse", t.bytes);
    }

    #[test]
    fn dense_delta_still_exact_when_everything_changed() {
        let base = params(256);
        let new: Vec<f32> = base.iter().map(|x| x * 1.5 + 0.01).collect();
        let plan =
            plan_reshard(&Layout::fsdp(256, 2), &Layout::tp_flat(256, 2)).unwrap();
        let mut dst = base.clone();
        let t = run_transfer_delta(&new, &base, &mut dst, &plan, 3, 4, None);
        assert!(dst.iter().zip(&new).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(t.max_abs_err, 0.0);
        // dense XOR: same wire size as full f32, never more
        assert_eq!(t.bytes, 256 * 4);
    }

    #[test]
    fn clustered_dense_delta_rle_compresses_and_roundtrips() {
        // 60% of the op changes, all in one contiguous block: past the
        // sparse break-even, but the zero runs outside the block make RLE
        // strictly smaller than raw dense XOR
        let base = params(500);
        let mut new = base.clone();
        for x in new.iter_mut().take(300) {
            *x += 1.0;
        }
        let op = TransferOp {
            src: 0,
            dst: 0,
            start: 0,
            len: 500,
        };
        let (pkt, bound) = encode_shard_delta(&new, &base, 1, 2, op, None);
        assert!(
            matches!(pkt.payload, ShardPayload::RleDelta { .. }),
            "clustered past-break-even delta must pick RLE"
        );
        assert_eq!(bound, 0.0);
        assert!(
            pkt.payload_bytes() < 500 * 4,
            "RLE must undercut dense XOR: {} B",
            pkt.payload_bytes()
        );
        let mut dst = base.clone();
        apply_packet(&mut dst, &pkt);
        assert!(
            dst.iter().zip(&new).all(|(a, b)| a.to_bits() == b.to_bits()),
            "RLE delta must reconstruct bit-exactly"
        );
    }

    #[test]
    fn rle_encode_decode_edge_cases() {
        // all zeros: one (n, 0) group
        assert_eq!(rle_encode_xor(&[0, 0, 0]), vec![3, 0]);
        // all literals: one (0, n) group + the words
        assert_eq!(rle_encode_xor(&[7, 8]), vec![0, 2, 7, 8]);
        // alternating groups, trailing zeros
        assert_eq!(
            rle_encode_xor(&[0, 5, 0, 0, 6, 0]),
            vec![1, 1, 5, 2, 1, 6, 1, 0]
        );
        assert_eq!(rle_encode_xor(&[]), Vec::<u32>::new());
    }

    #[test]
    fn topk_delta_error_within_reported_bound() {
        let base = params(1000);
        let mut new = base.clone();
        for (i, x) in new.iter_mut().enumerate() {
            *x += (i as f32 * 0.61).cos() * 0.01; // every element nudged
        }
        let plan =
            plan_reshard(&Layout::fsdp(1000, 4), &Layout::tp_flat(1000, 2)).unwrap();
        let mut dst = base.clone();
        let t = run_transfer_delta(&new, &base, &mut dst, &plan, 1, 2, Some(0.05));
        assert!(t.max_abs_err > 0.0, "top-k at 5% of a dense update must drop");
        assert!(
            t.max_abs_err <= t.err_bound,
            "err {} > bound {}",
            t.max_abs_err,
            t.err_bound
        );
        assert!(t.bytes < 1000 * 4, "capped sparse packing must beat full");
    }

    #[test]
    fn topk_falls_back_to_full_when_dense() {
        // k = 90% of the shard: sparse pairs would cost 1.8x full, so the
        // encoder must ship full f32 (exact, bound 0, no base fence)
        let base = params(64);
        let new: Vec<f32> = base.iter().map(|x| x + 1.0).collect();
        let op = TransferOp {
            src: 0,
            dst: 0,
            start: 0,
            len: 64,
        };
        let (pkt, bound) = encode_shard_delta(&new, &base, 1, 2, op, Some(58));
        assert!(matches!(pkt.payload, ShardPayload::F32(_)));
        assert_eq!(bound, 0.0);
        assert_eq!(pkt.base_version(), None);
        let mut dst = vec![0.0; 64]; // full payload needs no base seeding
        apply_packet(&mut dst, &pkt);
        assert_eq!(dst, new);
    }

    #[test]
    fn auto_encoding_adapts_to_update_density() {
        let base = params(400);
        let op = TransferOp {
            src: 0,
            dst: 0,
            start: 0,
            len: 400,
        };
        // sparse update (2%): auto must pick a delta form and stay bit-exact
        let mut sparse_new = base.clone();
        for i in (0..400).step_by(50) {
            sparse_new[i] += 0.25;
        }
        let (pkt, density) = encode_shard_auto(&sparse_new, &base, 1, 2, op);
        assert!(density < 0.05, "measured density {density}");
        assert!(
            pkt.base_version().is_some(),
            "sparse auto publish must ship a delta"
        );
        assert!(pkt.payload_bytes() < 400 * 4 / 4);
        let mut dst = base.clone();
        apply_packet(&mut dst, &pkt);
        assert!(dst
            .iter()
            .zip(&sparse_new)
            .all(|(a, b)| a.to_bits() == b.to_bits()));

        // dense update (every element): auto must ship self-contained f32
        let dense_new: Vec<f32> = base.iter().map(|x| x + 1.0).collect();
        let (pkt, density) = encode_shard_auto(&dense_new, &base, 1, 2, op);
        assert_eq!(density, 1.0);
        assert!(matches!(pkt.payload, ShardPayload::F32(_)));
        assert_eq!(pkt.base_version(), None, "full form carries no base fence");
        let mut dst = vec![0.0f32; 400]; // needs no base seeding
        apply_packet(&mut dst, &pkt);
        assert_eq!(dst, dense_new);
    }

    #[test]
    fn delta_base_version_is_tagged() {
        let base = vec![0.0f32; 16];
        let new = {
            let mut v = base.clone();
            v[3] = 9.0;
            v
        };
        let op = TransferOp {
            src: 0,
            dst: 0,
            start: 0,
            len: 16,
        };
        let (pkt, _) = encode_shard_delta(&new, &base, 41, 42, op, None);
        assert_eq!(pkt.version, 42);
        assert_eq!(pkt.base_version(), Some(41));
        assert_eq!(pkt.payload_bytes(), 8); // one (idx, val) pair
    }

    #[test]
    fn stale_version_is_tagged() {
        let src = params(16);
        let pkt = encode_shard(
            &src,
            7,
            TransferOp {
                src: 0,
                dst: 0,
                start: 0,
                len: 16,
            },
            ShardEncoding::F32,
        );
        assert_eq!(pkt.version, 7);
        assert_eq!(pkt.payload_bytes(), 64);
    }
}
