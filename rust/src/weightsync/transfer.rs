//! Per-shard versioned transfer with optional quantized encoding.
//!
//! Each [`TransferOp`] of a [`ReshardPlan`] becomes one [`ShardPacket`]: the
//! source rank encodes its interval (f32 passthrough or int8 symmetric
//! per-shard, reusing `model::quant`), the destination rank applies
//! it — dequantizing at attach — into its receive buffer. Packets carry the
//! weight version so receivers can fence: a packet for any version other
//! than the one currently staging is dropped, never mixed.
//!
//! Timing: each op is timed individually. On the cluster all links move in
//! parallel, so the modelled DDMA time for a publish is
//! [`TransferTiming::max_shard_secs`], while the single-core testbed pays
//! [`TransferTiming::total_secs`].

use std::time::Instant;

use crate::model::{quantize_int8, QuantizedParams};
use crate::runtime::ParamEntry;
use crate::weightsync::plan::{ReshardPlan, TransferOp};

/// Wire encoding for shard payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardEncoding {
    /// 4 bytes/elem, bit-exact
    F32,
    /// 1 byte/elem + one f32 scale per shard; the paper's fp8-generator
    /// analogue — the attached weights are a quantized snapshot of pi
    Int8,
}

/// One encoded shard in flight.
#[derive(Debug, Clone)]
pub struct ShardPacket {
    pub version: u64,
    pub op: TransferOp,
    pub payload: ShardPayload,
}

#[derive(Debug, Clone)]
pub enum ShardPayload {
    F32(Vec<f32>),
    Int8(QuantizedParams),
}

impl ShardPacket {
    /// Bytes on the wire (payload only; the op header is negligible).
    pub fn payload_bytes(&self) -> usize {
        match &self.payload {
            ShardPayload::F32(v) => v.len() * 4,
            ShardPayload::Int8(q) => q.data.len() + q.scales.len() * 4,
        }
    }
}

/// A shard viewed as a single-tensor layout, so the per-tensor quantizer in
/// `model::quant` applies per-shard unchanged.
fn shard_entry(len: usize) -> [ParamEntry; 1] {
    [ParamEntry {
        name: "shard".into(),
        shape: vec![len],
        offset: 0,
    }]
}

/// Encode one op's interval of `params` (the source rank's push).
pub fn encode_shard(
    params: &[f32],
    version: u64,
    op: TransferOp,
    encoding: ShardEncoding,
) -> ShardPacket {
    let chunk = &params[op.start..op.end()];
    let payload = match encoding {
        ShardEncoding::F32 => ShardPayload::F32(chunk.to_vec()),
        ShardEncoding::Int8 => {
            ShardPayload::Int8(quantize_int8(chunk, &shard_entry(chunk.len())))
        }
    };
    ShardPacket {
        version,
        op,
        payload,
    }
}

/// Apply a packet into the receive buffer (the destination rank's attach);
/// int8 payloads dequantize here, straight into `dst` — this is the publish
/// fan-out hot path (one call per op per subscriber), so no intermediate
/// allocation.
pub fn apply_packet(dst: &mut [f32], pkt: &ShardPacket) {
    let range = pkt.op.start..pkt.op.end();
    match &pkt.payload {
        ShardPayload::F32(v) => dst[range].copy_from_slice(v),
        ShardPayload::Int8(q) => {
            // same math as model::dequantize_int8 (one tensor, one scale),
            // written in place
            let scale = q.scales.first().copied().unwrap_or(1.0);
            for (out, x) in dst[range].iter_mut().zip(&q.data) {
                *out = *x as f32 * scale;
            }
        }
    }
}

/// Timing + fidelity record for one executed plan.
#[derive(Debug, Clone, Default)]
pub struct TransferTiming {
    /// encode+apply seconds per op, in plan order
    pub shard_secs: Vec<f64>,
    /// payload bytes moved
    pub bytes: usize,
    /// max |dst - src| over quantized ops (0.0 for pure-f32 plans)
    pub max_abs_err: f32,
    /// the worst-case bound the quantizer guarantees for this data
    /// (see [`crate::model::int8_error_bound`]); `max_abs_err <= err_bound`
    /// always holds
    pub err_bound: f32,
}

impl TransferTiming {
    /// Modelled cluster DDMA time: all links move in parallel, publish
    /// completes when the slowest shard lands.
    pub fn max_shard_secs(&self) -> f64 {
        self.shard_secs.iter().cloned().fold(0.0, f64::max)
    }

    /// Single-core testbed time (shards moved sequentially).
    pub fn total_secs(&self) -> f64 {
        self.shard_secs.iter().sum()
    }
}

/// Execute a full plan `src -> dst` at `version`: encode each op, apply it,
/// time it, and (for quantized plans) measure the realized round-trip error
/// against its bound.
pub fn run_transfer(
    params: &[f32],
    dst: &mut [f32],
    plan: &ReshardPlan,
    version: u64,
    encoding: ShardEncoding,
) -> TransferTiming {
    assert_eq!(params.len(), plan.num_params);
    assert_eq!(dst.len(), plan.num_params);
    let mut timing = TransferTiming::default();
    for &op in &plan.ops {
        let t0 = Instant::now();
        let pkt = encode_shard(params, version, op, encoding);
        timing.bytes += pkt.payload_bytes();
        apply_packet(dst, &pkt);
        timing.shard_secs.push(t0.elapsed().as_secs_f64());
        if encoding == ShardEncoding::Int8 {
            let src_chunk = &params[op.start..op.end()];
            let maxabs = src_chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            timing.err_bound = timing.err_bound.max(crate::model::int8_error_bound(maxabs));
            for (a, b) in src_chunk.iter().zip(&dst[op.start..op.end()]) {
                timing.max_abs_err = timing.max_abs_err.max((a - b).abs());
            }
        }
    }
    timing
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weightsync::layout::Layout;
    use crate::weightsync::plan::plan_reshard;

    fn params(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.37).sin() * 3.0).collect()
    }

    #[test]
    fn f32_transfer_is_exact() {
        let src = params(503);
        let plan =
            plan_reshard(&Layout::fsdp(503, 5), &Layout::tp_flat(503, 3)).unwrap();
        let mut dst = vec![0.0f32; 503];
        let t = run_transfer(&src, &mut dst, &plan, 1, ShardEncoding::F32);
        assert_eq!(dst, src);
        assert_eq!(t.bytes, 503 * 4);
        assert_eq!(t.max_abs_err, 0.0);
        assert_eq!(t.shard_secs.len(), plan.ops.len());
    }

    #[test]
    fn int8_transfer_within_bound_and_smaller() {
        let src = params(1024);
        let plan =
            plan_reshard(&Layout::fsdp(1024, 4), &Layout::tp_flat(1024, 4)).unwrap();
        let mut dst = vec![0.0f32; 1024];
        let t = run_transfer(&src, &mut dst, &plan, 1, ShardEncoding::Int8);
        assert!(t.max_abs_err > 0.0, "int8 roundtrip should not be exact");
        assert!(
            t.max_abs_err <= t.err_bound,
            "err {} > bound {}",
            t.max_abs_err,
            t.err_bound
        );
        // ~1 byte/elem + 4-byte scale per shard vs 4 bytes/elem
        assert!(t.bytes < 1024 * 2);
    }

    #[test]
    fn inplace_dequant_matches_model_dequantize() {
        // apply_packet's in-place int8 arm must agree bit-for-bit with the
        // reference model::dequantize_int8 it replaces on the hot path.
        let src = params(37);
        let op = TransferOp {
            src: 0,
            dst: 0,
            start: 5,
            len: 32,
        };
        let pkt = encode_shard(&src, 1, op, ShardEncoding::Int8);
        let mut dst = vec![0.0f32; 37];
        apply_packet(&mut dst, &pkt);
        let ShardPayload::Int8(q) = &pkt.payload else {
            panic!("int8 packet expected")
        };
        let reference = crate::model::dequantize_int8(q, &shard_entry(q.data.len()));
        assert_eq!(&dst[5..37], &reference[..]);
        // outside the op's interval stays untouched
        assert!(dst[..5].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn stale_version_is_tagged() {
        let src = params(16);
        let pkt = encode_shard(
            &src,
            7,
            TransferOp {
                src: 0,
                dst: 0,
                start: 0,
                len: 16,
            },
            ShardEncoding::F32,
        );
        assert_eq!(pkt.version, 7);
        assert_eq!(pkt.payload_bytes(), 64);
    }
}
