//! The sharded weight-sync plane (paper §5.2, Table 4).
//!
//! DDMA's efficiency claim is structural: weights move as many small
//! per-shard transfers over parallel links, not one monolithic broadcast, so
//! sync time scales with *shard* size while generators keep decoding until a
//! complete new version is ready. This module is that structure, end to end:
//!
//! ```text
//!   trainer (FSDP layout)                        generators (TP layout)
//!   ┌────┬────┬────┬────┐   ReshardPlan          ┌──────────┬──────────┐
//!   │ r0 │ r1 │ r2 │ r3 │ ──(min per-link ops)─► │ staging  │ staging  │
//!   └────┴────┴────┴────┘   ShardPacket stream   │ (ver N+1)│ (ver N+1)│
//!            │               f32 | int8+scale    ├──────────┼──────────┤
//!            │                                   │ front N  │ front N  │ ◄─ decode
//!            ▼                                   └────▲─────┴────▲─────┘
//!     per-shard timing                          swap_at_boundary (fenced)
//!     (DDMA time = max shard)
//! ```
//!
//! * [`layout`] — [`Layout`] shard maps: trainer-side FSDP (contiguous) and
//!   generator-side TP (per-tensor split) tilings of the flat vector.
//! * [`plan`] — [`plan_reshard`]: the minimal per-link [`TransferOp`]
//!   schedule between any two layouts (interval intersection sweep), plus
//!   the link-group partitions the background executor threads over:
//!   [`ReshardPlan::link_groups`] (per-destination-rank, the
//!   `sync_link_groups = 0` auto default) and
//!   [`ReshardPlan::link_groups_balanced`] (bandwidth-aware greedy
//!   largest-first over cumulative link volumes, used for explicit group
//!   counts so skewed destination layouts still load workers evenly).
//! * [`transfer`] — [`ShardPacket`] encode/apply with [`ShardEncoding`]:
//!   f32, int8-per-shard (via `model::quant`, dequantized at attach, error
//!   within [`crate::model::int8_error_bound`]), exact delta (sparse
//!   index+value or dense bitwise-XOR vs the previous published version,
//!   bit-exact), top-k sparse delta (k largest updates, error bounded
//!   by the largest dropped update, full-f32 fallback past the density
//!   break-even), and adaptive `auto` (measure the update density at
//!   encode time per publish, pick exact delta below the break-even and
//!   self-contained full f32 above it — [`encode_shard_auto`]).
//!   [`TransferTiming`] models DDMA time = max over parallel shards.
//! * [`swap`] — [`GeneratorSlot`]: double-buffered receive slots with
//!   version fencing (only complete versions promote, at a boundary the
//!   generator chooses) and base-version fencing (a delta packet against a
//!   base the staging buffer does not hold is rejected with
//!   [`RecvOutcome::BaseMismatch`] and re-sent as full).
//! * [`executor`] — [`StreamExecutor`]: the background streaming plane.
//!   One long-lived worker thread per link-group drains a latest-wins queue
//!   of publish jobs, so `WeightsBus::publish` is enqueue-and-return and
//!   the trainer never stalls on the fan-out. [`SyncMetrics`] splits
//!   publisher-blocked time from stream-side work.
//!
//! [`crate::ddma::WeightsBus`] is the facade over this plane; the
//! coordinator's async modes register one slot per generator worker and
//! record per-trajectory weight versions from the fenced swap. Multiple
//! trainer publishers may share one bus — versions are minted under a
//! single lock, so `wait_for` observers see one total order. The cluster
//! cost of a plan is modelled by
//! [`crate::ddma::topology::DdmaModel::plan_secs`].

pub mod executor;
pub mod layout;
pub mod plan;
pub mod swap;
pub mod transfer;

pub use executor::{StreamExecutor, SyncMetrics};
pub use layout::{contiguous_entries, even_entries, Layout, LayoutKind, ShardInterval};
pub use plan::{group_balance_ratio, plan_reshard, ReshardPlan, TransferOp};
pub use swap::{GeneratorSlot, RecvOutcome};
pub use transfer::{
    apply_packet, encode_shard, encode_shard_auto, encode_shard_delta, rle_encode_xor,
    run_transfer, run_transfer_delta, ShardEncoding, ShardPacket, ShardPayload, TransferTiming,
};
