//! The sharded weight-sync plane (paper §5.2, Table 4).
//!
//! DDMA's efficiency claim is structural: weights move as many small
//! per-shard transfers over parallel links, not one monolithic broadcast, so
//! sync time scales with *shard* size while generators keep decoding until a
//! complete new version is ready. This module is that structure, end to end:
//!
//! ```text
//!   trainer (FSDP layout)                        generators (TP layout)
//!   ┌────┬────┬────┬────┐   ReshardPlan          ┌──────────┬──────────┐
//!   │ r0 │ r1 │ r2 │ r3 │ ──(min per-link ops)─► │ staging  │ staging  │
//!   └────┴────┴────┴────┘   ShardPacket stream   │ (ver N+1)│ (ver N+1)│
//!            │               f32 | int8+scale    ├──────────┼──────────┤
//!            │                                   │ front N  │ front N  │ ◄─ decode
//!            ▼                                   └────▲─────┴────▲─────┘
//!     per-shard timing                          swap_at_boundary (fenced)
//!     (DDMA time = max shard)
//! ```
//!
//! * [`layout`] — [`Layout`] shard maps: trainer-side FSDP (contiguous) and
//!   generator-side TP (per-tensor split) tilings of the flat vector.
//! * [`plan`] — [`plan_reshard`]: the minimal per-link [`TransferOp`]
//!   schedule between any two layouts (interval intersection sweep).
//! * [`transfer`] — [`ShardPacket`] encode/apply with [`ShardEncoding`]
//!   (f32 or int8-per-shard via `model::quant`, dequantized at
//!   attach, error within [`crate::model::int8_error_bound`]) and
//!   [`TransferTiming`] (DDMA time = max over parallel shards).
//! * [`swap`] — [`GeneratorSlot`]: double-buffered receive slots with
//!   version fencing; decode stays on version N while N+1 streams in and
//!   swaps atomically at a sequence boundary.
//!
//! [`crate::ddma::WeightsBus`] is the facade over this plane; the
//! coordinator's async modes register one slot per generator worker and
//! record per-trajectory weight versions from the fenced swap. The cluster
//! cost of a plan is modelled by
//! [`crate::ddma::topology::DdmaModel::plan_secs`].

pub mod layout;
pub mod plan;
pub mod swap;
pub mod transfer;

pub use layout::{contiguous_entries, even_entries, Layout, LayoutKind, ShardInterval};
pub use plan::{plan_reshard, ReshardPlan, TransferOp};
pub use swap::GeneratorSlot;
pub use transfer::{
    apply_packet, encode_shard, run_transfer, ShardEncoding, ShardPacket, ShardPayload,
    TransferTiming,
};
