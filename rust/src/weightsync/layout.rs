//! Shard-map descriptors over the flat parameter vector.
//!
//! A [`Layout`] says which rank owns which half-open interval of the flat
//! `f32[P]` buffer. Two families matter for weight sync (paper §5.2):
//!
//! * **FSDP-style** (trainer side): the flat vector is split into `n_ranks`
//!   contiguous, near-equal shards — rank r owns one interval. This is the
//!   layout the optimizer state lives in, so it is the *source* of every
//!   publish.
//! * **TP-style** (generator side): each *tensor* is split across the
//!   `n_ranks` of a model-parallel group — rank r owns the r-th slice of
//!   every tensor, so its ownership is many scattered intervals. This is
//!   the layout the inference engine wants, so it is the *destination*.
//!
//! The two tilings disagree, which is exactly why resharding
//! ([`crate::weightsync::plan`]) is non-trivial: one trainer shard feeds
//! pieces of several generator ranks and vice versa.

use crate::runtime::ParamEntry;
use crate::util::error::{Error, Result};

/// One contiguous interval of the flat parameter vector owned by `rank`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardInterval {
    pub rank: usize,
    pub start: usize,
    pub len: usize,
}

impl ShardInterval {
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// Which family of tiling produced a layout (documentation + display only —
/// the planner works purely on intervals).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutKind {
    /// contiguous per-rank split of the whole flat vector
    Fsdp,
    /// per-tensor split across the model-parallel group
    Tp,
}

/// A disjoint cover of `[0, num_params)` by rank-owned intervals, sorted by
/// `start`. Construct via [`Layout::fsdp`] / [`Layout::tp`]; `validate`
/// enforces the cover invariant (and every constructor here satisfies it).
#[derive(Debug, Clone)]
pub struct Layout {
    pub kind: LayoutKind,
    pub n_ranks: usize,
    pub num_params: usize,
    /// sorted by `start`; disjoint; covers `[0, num_params)` exactly
    pub shards: Vec<ShardInterval>,
}

/// Split `[start, start+len)` into `n` near-equal contiguous pieces (the
/// first `len % n` pieces get one extra element); zero-length pieces are
/// skipped. Returns `(rank, start, len)` per surviving piece.
fn split_interval(start: usize, len: usize, n: usize) -> Vec<(usize, usize, usize)> {
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut at = start;
    for r in 0..n {
        let l = base + usize::from(r < extra);
        if l > 0 {
            out.push((r, at, l));
        }
        at += l;
    }
    out
}

/// Build a contiguous tensor map from per-tensor sizes — the synthetic
/// analogue of the manifest's `param_layout` (benches, examples, tests).
pub fn contiguous_entries(sizes: &[usize]) -> Vec<ParamEntry> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0;
    for (i, s) in sizes.iter().enumerate() {
        out.push(ParamEntry {
            name: format!("t{i}"),
            shape: vec![*s],
            offset: off,
        });
        off += s;
    }
    out
}

/// `n_tensors` near-equal contiguous entries tiling `[0, num_params)`.
pub fn even_entries(num_params: usize, n_tensors: usize) -> Vec<ParamEntry> {
    assert!(n_tensors > 0, "need at least one tensor");
    split_interval(0, num_params, n_tensors)
        .into_iter()
        .map(|(i, offset, len)| ParamEntry {
            name: format!("t{i}"),
            shape: vec![len],
            offset,
        })
        .collect()
}

impl Layout {
    /// Trainer-side FSDP layout: `n_ranks` contiguous shards over the flat
    /// vector.
    pub fn fsdp(num_params: usize, n_ranks: usize) -> Layout {
        assert!(n_ranks > 0, "layout needs at least one rank");
        let shards = split_interval(0, num_params, n_ranks)
            .into_iter()
            .map(|(rank, start, len)| ShardInterval { rank, start, len })
            .collect();
        Layout {
            kind: LayoutKind::Fsdp,
            n_ranks,
            num_params,
            shards,
        }
    }

    /// Generator-side TP layout: every tensor in `entries` is split across
    /// the `n_ranks` model-parallel ranks, so rank r owns the r-th slice of
    /// each tensor. `entries` must tile `[0, num_params)` contiguously in
    /// offset order (the manifest's `param_layout` does).
    pub fn tp(num_params: usize, n_ranks: usize, entries: &[ParamEntry]) -> Result<Layout> {
        assert!(n_ranks > 0, "layout needs at least one rank");
        let mut shards = Vec::with_capacity(entries.len() * n_ranks);
        let mut expect = 0usize;
        for e in entries {
            if e.offset != expect {
                return Err(Error::Config(format!(
                    "param layout gap: entry '{}' at offset {}, expected {expect}",
                    e.name, e.offset
                )));
            }
            let len: usize = e.shape.iter().product();
            for (rank, start, l) in split_interval(e.offset, len, n_ranks) {
                shards.push(ShardInterval {
                    rank,
                    start,
                    len: l,
                });
            }
            expect += len;
        }
        if expect != num_params {
            return Err(Error::Config(format!(
                "param layout covers {expect} elements, expected {num_params}"
            )));
        }
        Ok(Layout {
            kind: LayoutKind::Tp,
            n_ranks,
            num_params,
            shards,
        })
    }

    /// TP layout with no tensor map available: treat the flat vector as one
    /// tensor (degenerates to the FSDP tiling, but tagged TP).
    pub fn tp_flat(num_params: usize, n_ranks: usize) -> Layout {
        let mut l = Layout::fsdp(num_params, n_ranks);
        l.kind = LayoutKind::Tp;
        l
    }

    /// Check the disjoint-cover invariant.
    pub fn validate(&self) -> Result<()> {
        let mut expect = 0usize;
        for s in &self.shards {
            if s.start != expect {
                return Err(Error::Config(format!(
                    "layout hole/overlap at {}: shard starts at {}",
                    expect, s.start
                )));
            }
            if s.rank >= self.n_ranks {
                return Err(Error::Config(format!(
                    "shard rank {} out of range (n_ranks {})",
                    s.rank, self.n_ranks
                )));
            }
            expect = s.end();
        }
        if expect != self.num_params {
            return Err(Error::Config(format!(
                "layout covers {expect} elements, expected {}",
                self.num_params
            )));
        }
        Ok(())
    }

    /// Elements owned by `rank` (its shard-group size).
    pub fn rank_elems(&self, rank: usize) -> usize {
        self.shards
            .iter()
            .filter(|s| s.rank == rank)
            .map(|s| s.len)
            .sum()
    }

    /// The largest per-rank ownership — at fixed shard size this is what
    /// cluster DDMA time scales with.
    pub fn max_rank_elems(&self) -> usize {
        (0..self.n_ranks).map(|r| self.rank_elems(r)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsdp_covers_and_balances() {
        for (n, ranks) in [(100, 1), (100, 7), (5, 8), (1000, 16)] {
            let l = Layout::fsdp(n, ranks);
            l.validate().unwrap();
            let max = l.max_rank_elems();
            let min = (0..ranks).map(|r| l.rank_elems(r)).min().unwrap();
            assert!(max - min <= 1, "unbalanced: {min}..{max}");
        }
    }

    #[test]
    fn tp_splits_every_tensor() {
        let es = contiguous_entries(&[64, 32, 10]);
        let l = Layout::tp(106, 4, &es).unwrap();
        l.validate().unwrap();
        // rank 0 owns the head slice of each tensor: 16 + 8 + 3 = 27
        assert_eq!(l.rank_elems(0), 16 + 8 + 3);
        // scattered ownership: more intervals than ranks
        assert!(l.shards.len() > l.n_ranks);
    }

    #[test]
    fn tp_rejects_gappy_entries() {
        let mut es = contiguous_entries(&[10, 10]);
        es[1].offset = 15;
        assert!(Layout::tp(25, 2, &es).is_err());
    }

    #[test]
    fn even_entries_tile_exactly() {
        for (n, k) in [(100, 7), (5, 8), (16, 1)] {
            let es = even_entries(n, k);
            let total: usize = es
                .iter()
                .map(|e| e.shape.iter().product::<usize>())
                .sum();
            assert_eq!(total, n);
            Layout::tp(n, 2, &es).unwrap().validate().unwrap();
        }
    }

    #[test]
    fn tp_flat_matches_fsdp_tiling() {
        let a = Layout::tp_flat(97, 3);
        let b = Layout::fsdp(97, 3);
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.kind, LayoutKind::Tp);
    }
}
