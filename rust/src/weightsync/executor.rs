//! Background streaming weight-sync executor: per-link-group transfer
//! threads with latest-wins coalescing.
//!
//! The inline DDMA facade streams a publish's reshard plan into every
//! generator slot *on the publisher's thread* — the trainer stalls for the
//! whole encode + fan-out, which is exactly the synchronous bubble the
//! paper's overlapped sync removes (§5.2, Table 4). This module moves that
//! work onto long-lived worker threads, one per **link-group** (the ops
//! bound for one destination rank — the testbed analogue of one dedicated
//! transfer worker per NVLink/IB link, as in AsyncFlow's streaming
//! parameter-update workers):
//!
//! ```text
//!   publisher(s) ── enqueue(job) ──► pending[g] (one slot per link-group
//!        │    (returns immediately)      PER PUBLISHER; latest-wins per
//!        ▼                               publisher: a newer version
//!   master snapshot swap                 supersedes that publisher's own
//!   (latest()/wait_for() exact,          undrained job, never a peer's)
//!    version order total across               │ worker thread per group,
//!    all publishers)                          ▼ oldest pending version first
//!                                     encode op → recv() into every
//!                                     GeneratorSlot (version fence +
//!                                     base-version fence; stale-base deltas
//!                                     re-sent as full f32)
//! ```
//!
//! With one publisher this is exactly the original latest-wins queue. With
//! a trainer fleet, per-publisher slots + oldest-first draining keep a
//! lagging replica's version fence from being starved by a faster peer
//! (fairness test: `fleet_publishers_are_not_starved`).
//!
//! Correctness leans entirely on the receive-side fences
//! ([`crate::weightsync::swap`]): a slot promotes only a *complete* staged
//! version, packets for superseded versions are dropped, and a delta packet
//! against a base the staging buffer does not hold is rejected and re-sent
//! self-contained. Worker threads therefore need no cross-group
//! coordination — any interleaving converges every slot to the newest
//! fully-streamed version.
//!
//! [`SyncMetrics`] is the shared counter block: the bus accounts
//! publisher-side blocked time, the executor accounts stream-side work, and
//! `benches/weightsync_overlap.rs` reports both (`publish_blocked_secs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::model::VersionedParams;
use crate::trace;
use crate::weightsync::plan::{ReshardPlan, TransferOp};
use crate::weightsync::swap::{GeneratorSlot, RecvOutcome};
use crate::weightsync::transfer::{
    encode_shard, encode_shard_auto, encode_shard_delta, ShardEncoding, ShardPacket,
    ShardPayload,
};

/// Shared counters for one weight-sync plane. The bus owns the publisher
/// side, the executor (when spawned) the streaming side; both hold the same
/// `Arc`.
#[derive(Debug, Default)]
pub struct SyncMetrics {
    /// completed publishes (version mints)
    pub publishes: AtomicU64,
    /// nanoseconds publishers spent blocked inside `publish` — with the
    /// background executor this is enqueue-and-return, inline it is the
    /// whole encode + fan-out
    pub publish_blocked_nanos: AtomicU64,
    /// sum over sampled stream jobs of the slowest op's seconds (the
    /// modelled parallel DDMA time), with its divisor below
    pub shard_max_nanos: AtomicU64,
    pub shard_max_samples: AtomicU64,
    /// payload bytes streamed to generator slots
    pub bytes_streamed: AtomicU64,
    /// background jobs superseded in a link-group queue before streaming
    /// (latest-wins coalescing)
    pub coalesced_jobs: AtomicU64,
    /// delta packets re-sent as full f32 after a base-version fence reject
    pub delta_full_resends: AtomicU64,
    /// sparse delta packets shipped
    pub sparse_packets: AtomicU64,
    /// zero-run-encoded dense-XOR delta packets shipped
    pub rle_packets: AtomicU64,
    /// adaptive-encoding ops that shipped self-contained full f32 (the
    /// measured density was at or above the sparse break-even)
    pub auto_full_ops: AtomicU64,
    /// adaptive-encoding ops that shipped an exact delta
    pub auto_delta_ops: AtomicU64,
    /// sum of measured per-op update densities, in parts per million (so
    /// the ultra-sparse regimes auto targets don't round to zero), with
    /// its sample count below (adaptive encoding only) — the density row
    /// of `BENCH_weightsync.json`
    pub density_ppm_sum: AtomicU64,
    pub density_samples: AtomicU64,
    /// nanoseconds worker threads spent streaming (background mode)
    pub stream_nanos: AtomicU64,
}

impl SyncMetrics {
    /// Total publisher-side blocked seconds.
    pub fn publish_blocked_secs(&self) -> f64 {
        self.publish_blocked_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Mean publisher-side blocked seconds per publish.
    pub fn mean_publish_blocked_secs(&self) -> f64 {
        let n = self.publishes.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.publish_blocked_secs() / n as f64
        }
    }

    /// Mean slowest-shard seconds per sampled stream job (inline: one
    /// sample per publish with subscribers; background: one per link-group
    /// job).
    pub fn mean_shard_max_secs(&self) -> f64 {
        let n = self.shard_max_samples.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.shard_max_nanos.load(Ordering::Relaxed) as f64 / 1e9 / n as f64
        }
    }

    /// Mean measured update density across adaptive-encoding ops (0.0 when
    /// the plane never ran `sync_encoding=auto`).
    pub fn mean_update_density(&self) -> f64 {
        let n = self.density_samples.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.density_ppm_sum.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }
}

/// One enqueued publish: the minted snapshot plus the delta base (the
/// previously published snapshot) when the plane runs a delta encoding.
pub(crate) struct PublishJob {
    pub params: Arc<VersionedParams>,
    pub base: Option<Arc<VersionedParams>>,
    /// registered bus publisher that minted this version. Coalescing is
    /// scoped per publisher: a trainer replica's newer publish supersedes
    /// only its OWN undrained job, never a fleet peer's — pure latest-wins
    /// across publishers would let a fast replica starve a lagging one's
    /// version fence indefinitely.
    pub publisher: usize,
}

/// Open staging for `version` on every slot (idempotent per version; the
/// delta flavour seeds each staging from its slot's front and arms the
/// base-version fence).
pub(crate) fn begin_on(subs: &[Arc<GeneratorSlot>], version: u64, expected: usize, delta: bool) {
    for slot in subs {
        if delta {
            slot.begin_delta(version, expected);
        } else {
            slot.begin(version, expected);
        }
    }
}

/// Encode one op once and fan it out to every slot, re-sending as full f32
/// wherever the base-version fence rejects a delta. Returns payload bytes
/// moved (primary once, plus the fallback if one was needed — matching the
/// inline path's op-granular accounting).
// internal fan-out kernel shared by the inline and background paths
#[allow(clippy::too_many_arguments)]
pub(crate) fn fan_out_op(
    data: &[f32],
    base: Option<&VersionedParams>,
    version: u64,
    op: TransferOp,
    encoding: ShardEncoding,
    topk_frac: f64,
    subs: &[Arc<GeneratorSlot>],
    metrics: &SyncMetrics,
) -> usize {
    let pkt = match (encoding, base) {
        (ShardEncoding::Delta, Some(b)) => {
            encode_shard_delta(data, &b.data, b.version, version, op, None).0
        }
        (ShardEncoding::TopK, Some(b)) => {
            let k = ((op.len as f64 * topk_frac).ceil() as usize).max(1);
            encode_shard_delta(data, &b.data, b.version, version, op, Some(k)).0
        }
        (ShardEncoding::Auto, Some(b)) => {
            // adaptive: measure density at encode time, pick full vs delta
            let (pkt, density) = encode_shard_auto(data, &b.data, b.version, version, op);
            metrics
                .density_ppm_sum
                .fetch_add((density * 1e6).round() as u64, Ordering::Relaxed);
            metrics.density_samples.fetch_add(1, Ordering::Relaxed);
            if matches!(pkt.payload, ShardPayload::F32(_)) {
                metrics.auto_full_ops.fetch_add(1, Ordering::Relaxed);
            } else {
                metrics.auto_delta_ops.fetch_add(1, Ordering::Relaxed);
            }
            pkt
        }
        // first publish of a delta plane has no base yet -> full f32
        _ => encode_shard(data, version, op, encoding),
    };
    match pkt.payload {
        ShardPayload::SparseDelta { .. } => {
            metrics.sparse_packets.fetch_add(1, Ordering::Relaxed);
        }
        ShardPayload::RleDelta { .. } => {
            metrics.rle_packets.fetch_add(1, Ordering::Relaxed);
        }
        _ => {}
    }
    let mut bytes = pkt.payload_bytes();
    let mut full_resend: Option<ShardPacket> = None;
    for slot in subs {
        if slot.recv(&pkt) == RecvOutcome::BaseMismatch {
            let full = full_resend
                .get_or_insert_with(|| encode_shard(data, version, op, ShardEncoding::F32));
            slot.recv(full);
            metrics.delta_full_resends.fetch_add(1, Ordering::Relaxed);
        }
    }
    if let Some(full) = full_resend {
        bytes += full.payload_bytes();
    }
    bytes
}

struct ExecState {
    /// per link-group: one latest-wins slot PER PUBLISHER (at most one
    /// undrained job per (group, publisher) pair — the fleet-fair
    /// coalescing policy; a solo publisher degenerates to the original
    /// single-slot latest-wins)
    pending: Vec<Vec<(usize, Arc<PublishJob>)>>,
    /// link-group workers currently streaming a job
    busy: usize,
    shutdown: bool,
}

struct ExecInner {
    /// ops per link-group (partitioned by destination rank)
    groups: Vec<Vec<TransferOp>>,
    /// the version fence expects the FULL plan's op count on every slot
    expected_ops: usize,
    encoding: ShardEncoding,
    topk_frac: f64,
    subscribers: Arc<Mutex<Vec<Arc<GeneratorSlot>>>>,
    metrics: Arc<SyncMetrics>,
    state: Mutex<ExecState>,
    work_cv: Condvar,
    idle_cv: Condvar,
}

/// The background streaming executor: one long-lived worker thread per
/// link-group, each draining a latest-wins queue of publish jobs. Spawned
/// by [`crate::ddma::WeightsBus`] when background sync is configured;
/// dropping it delivers any still-pending job, then joins the workers.
pub struct StreamExecutor {
    inner: Arc<ExecInner>,
    workers: Vec<JoinHandle<()>>,
}

impl StreamExecutor {
    pub(crate) fn spawn(
        plan: &ReshardPlan,
        link_groups: usize, // 0 = auto: one per destination rank
        encoding: ShardEncoding,
        topk_frac: f64,
        subscribers: Arc<Mutex<Vec<Arc<GeneratorSlot>>>>,
        metrics: Arc<SyncMetrics>,
    ) -> StreamExecutor {
        // 0 = auto: one group per destination rank (the original
        // rank-modulo behaviour, trivially exact at n = n_dst); an explicit
        // count uses the bandwidth-aware LPT partition so worker streams
        // stay element-balanced under skewed destination layouts.
        let groups = if link_groups == 0 {
            plan.link_groups(plan.n_dst.max(1))
        } else {
            plan.link_groups_balanced(link_groups)
        };
        let n = groups.len();
        let inner = Arc::new(ExecInner {
            expected_ops: plan.ops.len(),
            groups,
            encoding,
            topk_frac,
            subscribers,
            metrics,
            state: Mutex::new(ExecState {
                pending: (0..n).map(|_| Vec::new()).collect(),
                busy: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|g| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("weightsync-link{g}"))
                    .spawn(move || worker_loop(&inner, g))
                    .expect("spawn weightsync link worker")
            })
            .collect();
        StreamExecutor { inner, workers }
    }

    pub fn n_link_groups(&self) -> usize {
        self.inner.groups.len()
    }

    /// Hand a publish to the link-group workers and return immediately.
    /// Latest-wins per publisher: a job from the SAME publisher still
    /// pending in a group's queue is superseded (its packets would be
    /// fenced off anyway once the newer version begins staging), while
    /// other publishers' pending jobs are left alone — so a lagging
    /// trainer replica's version is streamed, not starved, under
    /// link-group contention.
    pub(crate) fn enqueue(&self, job: PublishJob) {
        let job = Arc::new(job);
        let mut st = self.inner.state.lock().unwrap();
        if st.shutdown {
            return;
        }
        for slots in st.pending.iter_mut() {
            match slots.iter_mut().find(|(p, _)| *p == job.publisher) {
                Some(entry) => {
                    entry.1 = job.clone();
                    self.inner.metrics.coalesced_jobs.fetch_add(1, Ordering::Relaxed);
                }
                None => slots.push((job.publisher, job.clone())),
            }
        }
        drop(st);
        self.inner.work_cv.notify_all();
    }

    /// Block until every enqueued job has streamed (test/bench
    /// synchronization point; generators normally just keep decoding and
    /// pick the version up at their next boundary).
    pub fn flush(&self) {
        let mut st = self.inner.state.lock().unwrap();
        while !st.shutdown && (st.busy > 0 || st.pending.iter().any(|p| !p.is_empty())) {
            st = self.inner.idle_cv.wait(st).unwrap();
        }
    }
}

impl Drop for StreamExecutor {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        self.inner.idle_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: &ExecInner, g: usize) {
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                // oldest version first: each publisher's versions then
                // stream in mint order, which keeps a lagging publisher's
                // delta base chain intact and its version fence honest —
                // streaming a newer peer version first would fence the
                // older one off at the slots
                let next = st.pending[g]
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, (_, j))| j.params.version)
                    .map(|(i, _)| i);
                if let Some(i) = next {
                    let (_, job) = st.pending[g].swap_remove(i);
                    st.busy += 1;
                    break job;
                }
                if st.shutdown {
                    return; // pending drained: deliver-then-exit is graceful
                }
                st = inner.work_cv.wait(st).unwrap();
            }
        };
        stream_group(inner, g, &job);
        let mut st = inner.state.lock().unwrap();
        st.busy -= 1;
        if st.busy == 0 && st.pending.iter().all(|p| p.is_empty()) {
            inner.idle_cv.notify_all();
        }
    }
}

/// Stream one job's link-group ops into every registered slot.
fn stream_group(inner: &ExecInner, g: usize, job: &PublishJob) {
    let subs: Vec<Arc<GeneratorSlot>> = inner.subscribers.lock().unwrap().clone();
    if subs.is_empty() {
        return;
    }
    let t0 = Instant::now();
    let version = job.params.version;
    // sync_overlap: this stream runs on a `weightsync-link{g}` worker
    // while decode keeps going — the overlapped region the DES models
    let _span = trace::span_with(trace::SYNC_OVERLAP, version as f64);
    begin_on(&subs, version, inner.expected_ops, inner.encoding.is_delta());
    let mut bytes = 0usize;
    let mut max_op = 0f64;
    for &op in &inner.groups[g] {
        let t_op = Instant::now();
        bytes += fan_out_op(
            &job.params.data,
            job.base.as_deref(),
            version,
            op,
            inner.encoding,
            inner.topk_frac,
            &subs,
            &inner.metrics,
        );
        max_op = max_op.max(t_op.elapsed().as_secs_f64());
    }
    let m = &inner.metrics;
    m.bytes_streamed.fetch_add(bytes as u64, Ordering::Relaxed);
    m.shard_max_nanos
        .fetch_add((max_op * 1e9) as u64, Ordering::Relaxed);
    m.shard_max_samples.fetch_add(1, Ordering::Relaxed);
    m.stream_nanos
        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weightsync::layout::Layout;
    use crate::weightsync::plan::plan_reshard;

    fn spawn_exec(
        n: usize,
        encoding: ShardEncoding,
        groups: usize,
    ) -> (StreamExecutor, Arc<Mutex<Vec<Arc<GeneratorSlot>>>>, Arc<SyncMetrics>) {
        let plan = plan_reshard(&Layout::fsdp(n, 4), &Layout::tp_flat(n, 3)).unwrap();
        let subs: Arc<Mutex<Vec<Arc<GeneratorSlot>>>> = Arc::new(Mutex::new(Vec::new()));
        let metrics = Arc::new(SyncMetrics::default());
        let exec =
            StreamExecutor::spawn(&plan, groups, encoding, 0.01, subs.clone(), metrics.clone());
        (exec, subs, metrics)
    }

    #[test]
    fn background_stream_converges_to_latest_version() {
        let n = 192;
        let (exec, subs, metrics) = spawn_exec(n, ShardEncoding::F32, 0);
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; n])));
        subs.lock().unwrap().push(slot.clone());

        let rounds = 100u64;
        for v in 1..=rounds {
            let data = vec![v as f32; n];
            exec.enqueue(PublishJob {
                params: Arc::new(VersionedParams::new(v, data)),
                base: None,
                publisher: 0,
            });
        }
        exec.flush();
        let snap = slot.swap_at_boundary().expect("latest version staged");
        assert_eq!(snap.version, rounds, "slot must converge to the max version");
        assert!(snap.data.iter().all(|x| *x == rounds as f32));
        // every enqueued group-job was either streamed (one shard-max
        // sample) or coalesced away — none lost
        let samples = metrics.shard_max_samples.load(Ordering::Relaxed);
        let coalesced = metrics.coalesced_jobs.load(Ordering::Relaxed);
        assert_eq!(
            samples + coalesced,
            rounds * exec.n_link_groups() as u64,
            "jobs must be streamed or coalesced, never dropped"
        );
        assert!(metrics.bytes_streamed.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn delta_stream_reconstructs_exactly_across_versions() {
        let n = 256;
        let (exec, subs, _metrics) = spawn_exec(n, ShardEncoding::Delta, 2);
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; n])));
        subs.lock().unwrap().push(slot.clone());

        let mut prev = Arc::new(VersionedParams::new(0, vec![0.0; n]));
        for v in 1..=20u64 {
            let mut data = prev.data.as_ref().clone();
            data[(v as usize * 13) % n] = v as f32; // sparse update
            let snap = Arc::new(VersionedParams::new(v, data));
            exec.enqueue(PublishJob {
                params: snap.clone(),
                base: Some(prev.clone()),
                publisher: 0,
            });
            // flush per publish so every delta lands on its exact base —
            // whether the slot swapped or not, the staging seed tracks it
            exec.flush();
            prev = snap;
            if v % 3 == 0 {
                // generator swaps only sometimes: later deltas then hit a
                // stale front base and must recover via full re-sends
                slot.swap_at_boundary();
            }
        }
        exec.flush();
        while slot.swap_at_boundary().is_some() {}
        let front = slot.attach();
        assert_eq!(front.version, 20);
        assert!(
            front
                .data
                .iter()
                .zip(prev.data.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "delta-streamed weights must match the published snapshot bit-exactly"
        );
    }

    #[test]
    fn auto_stream_splits_full_and_delta_by_density() {
        let n = 256;
        let (exec, subs, metrics) = spawn_exec(n, ShardEncoding::Auto, 1);
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; n])));
        subs.lock().unwrap().push(slot.clone());

        let mut prev = Arc::new(VersionedParams::new(0, vec![0.0; n]));
        for v in 1..=10u64 {
            let mut data = prev.data.as_ref().clone();
            if v % 2 == 0 {
                data[(v as usize * 31) % n] += 1.0; // sparse publish
            } else {
                for x in data.iter_mut() {
                    *x += 0.5; // dense publish
                }
            }
            let snap = Arc::new(VersionedParams::new(v, data));
            exec.enqueue(PublishJob {
                params: snap.clone(),
                base: Some(prev.clone()),
                publisher: 0,
            });
            exec.flush();
            prev = snap;
        }
        while slot.swap_at_boundary().is_some() {}
        let front = slot.attach();
        assert_eq!(front.version, 10);
        assert!(
            front
                .data
                .iter()
                .zip(prev.data.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "auto-streamed weights must match the published snapshot bit-exactly"
        );
        // both regimes must have been picked at least once, and the mean
        // measured density must sit strictly between them
        assert!(metrics.auto_full_ops.load(Ordering::Relaxed) > 0);
        assert!(metrics.auto_delta_ops.load(Ordering::Relaxed) > 0);
        let d = metrics.mean_update_density();
        assert!(d > 0.0 && d < 1.0, "mean density {d} out of range");
    }

    #[test]
    fn fleet_publishers_are_not_starved() {
        // Two trainer replicas publishing through one plan: versions 1, 3,
        // 5 from publisher 0 interleave with 2, 4 from publisher 1 while
        // the single link-group worker is busy streaming v1. Per-publisher
        // coalescing must supersede only a publisher's OWN pending job —
        // pure latest-wins would collapse all four queued versions into
        // one slot and starve the lagging publisher's version fence.
        let n = 100_000;
        let (exec, subs, metrics) = spawn_exec(n, ShardEncoding::F32, 1);
        let slots: Vec<Arc<GeneratorSlot>> = (0..8)
            .map(|_| {
                let s = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; n])));
                subs.lock().unwrap().push(s.clone());
                s
            })
            .collect();
        exec.enqueue(PublishJob {
            params: Arc::new(VersionedParams::new(1, vec![1.0; n])),
            base: None,
            publisher: 0,
        });
        // wait until the worker picked v1 up (or already finished it) so
        // the queue state built below is deterministic
        while exec.inner.state.lock().unwrap().busy == 0
            && metrics.shard_max_samples.load(Ordering::Relaxed) == 0
        {
            std::thread::yield_now();
        }
        for (v, p) in [(2u64, 1usize), (3, 0), (4, 1), (5, 0)] {
            exec.enqueue(PublishJob {
                params: Arc::new(VersionedParams::new(v, vec![v as f32; n])),
                base: None,
                publisher: p,
            });
        }
        exec.flush();
        for s in &slots {
            let snap = s.swap_at_boundary().expect("latest version staged");
            assert_eq!(snap.version, 5, "slots converge to the newest version");
        }
        let samples = metrics.shard_max_samples.load(Ordering::Relaxed);
        let coalesced = metrics.coalesced_jobs.load(Ordering::Relaxed);
        assert_eq!(samples + coalesced, 5, "streamed or coalesced, never dropped");
        // fairness: each publisher may supersede at most its own earlier
        // pending job (one each here); latest-wins across publishers would
        // coalesce 3
        assert!(
            coalesced <= 2,
            "a publisher must only supersede its own pending job (coalesced {coalesced})"
        );
        // both publishers' terminal versions (and v1) must actually stream
        assert!(
            samples >= 3,
            "a lagging publisher's version was starved (streamed {samples})"
        );
    }

    #[test]
    fn executor_with_no_subscribers_is_inert() {
        let (exec, _subs, metrics) = spawn_exec(64, ShardEncoding::F32, 1);
        exec.enqueue(PublishJob {
            params: Arc::new(VersionedParams::new(1, vec![1.0; 64])),
            base: None,
            publisher: 0,
        });
        exec.flush();
        assert_eq!(metrics.bytes_streamed.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.shard_max_samples.load(Ordering::Relaxed), 0);
    }
}
