//! Run-journal subsystem: wire-form fixpoint and streaming-reader
//! property tests (random record mixes, mid-record truncation, empty
//! files, interior corruption), resume planning over synthetic journals,
//! and artifact-gated kill→resume count-parity and record→replay
//! bit-parity suites.

use llamarl::config;
use llamarl::coordinator::{run_training, Mode, PipelineConfig, TrainStepRecord};
use llamarl::data::{Difficulty, Problem, PromptTask};
use llamarl::dataplane::{ConsumeReason, PartialRollout};
use llamarl::journal::record::{trajectory_from_value, trajectory_to_value};
use llamarl::journal::{
    compare_steps, find_checkpoint_state, plan_resume, JournalReader, JournalRecord,
    JournalWriter, SnapshotRecord, StoreSnapshot,
};
use llamarl::rl::{FinishReason, Trajectory};
use llamarl::util::json::Value;
use llamarl::util::prop::{run_prop, Gen};
use llamarl::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("llamarl_journal_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

// ---------------------------------------------------------------------------
// Generators

fn any_traj(g: &mut Gen) -> Trajectory {
    let rlen = g.usize(1, 6);
    Trajectory {
        group_id: g.i64(0, 1000) as u64,
        replica: g.usize(0, 3),
        n_replicas: 4,
        problem: Problem {
            prompt: format!("{}+{}=", g.i64(0, 9), g.i64(0, 9)),
            answer: format!("{}", g.i64(0, 18)),
            difficulty: *g.choice(&[
                Difficulty::Add1,
                Difficulty::AddSub2,
                Difficulty::Mul,
                Difficulty::ThreeTerm,
            ]),
        },
        prompt_tokens: (0..g.usize(1, 4)).map(|_| g.i64(0, 60) as i32).collect(),
        response_tokens: (0..rlen).map(|_| g.i64(0, 60) as i32).collect(),
        behavior_logp: (0..rlen).map(|_| g.f64(-8.0, 0.0) as f32).collect(),
        gen_version: g.i64(0, 50) as u64,
        chunks: g.usize(1, 3) as u32,
        finish: if g.bool() {
            FinishReason::Eos
        } else {
            FinishReason::Length
        },
        reward: g.f64(-1.0, 1.0) as f32,
        advantage: g.f64(-2.0, 2.0) as f32,
    }
}

fn any_step(g: &mut Gen) -> TrainStepRecord {
    TrainStepRecord {
        step: g.i64(1, 100) as u64,
        replica: g.usize(0, 3),
        wall_secs: g.f64(0.0, 5.0),
        loss: g.f64(-2.0, 2.0),
        reward_mean: g.f64(-1.0, 1.0),
        mean_ratio: g.f64(0.5, 1.5),
        clip_frac: g.f64(0.0, 1.0),
        approx_kl: g.f64(0.0, 0.2),
        entropy: g.f64(0.0, 4.0),
        // NaN exercises the null wire form (JSON has no NaN)
        grad_norm: if g.bool() { g.f64(0.0, 10.0) } else { f64::NAN },
        mean_lag: g.f64(0.0, 4.0),
        max_lag: g.i64(0, 8) as u64,
        rows: g.usize(1, 16),
    }
}

fn any_snapshot(g: &mut Gen) -> SnapshotRecord {
    let store = if g.bool() {
        let partials = if g.bool() {
            let len = g.usize(2, 6);
            let plen = g.usize(1, len.min(3));
            vec![PartialRollout {
                task: PromptTask {
                    group_id: g.i64(0, 100) as u64,
                    replica: g.usize(0, 3),
                    n_replicas: 4,
                    problem: Problem {
                        prompt: "2+2=".into(),
                        answer: "4".into(),
                        difficulty: Difficulty::Add1,
                    },
                    prompt_tokens: (0..plen).map(|_| g.i64(0, 60) as i32).collect(),
                },
                tokens: (0..len).map(|_| g.i64(0, 60) as i32).collect(),
                prompt_len: plen,
                logps: (0..len - plen).map(|_| g.f64(-8.0, 0.0) as f32).collect(),
                chunks: g.usize(1, 3) as u32,
                gen_version: g.i64(0, 50) as u64,
            }]
        } else {
            Vec::new()
        };
        Some(StoreSnapshot {
            next_seq: g.i64(0, 500) as u64,
            watermark: g.i64(0, 50) as u64,
            rows: (0..g.usize(0, 3))
                .map(|i| (g.i64(0, 500) as u64 * 4 + i as u64, any_traj(g)))
                .collect(),
            partials,
        })
    } else {
        None
    };
    let mut nodes = std::collections::BTreeMap::new();
    for i in 0..g.usize(0, 3) {
        nodes.insert(
            format!("gen{i}"),
            if g.bool() { "start" } else { "stop" }.to_string(),
        );
    }
    SnapshotRecord {
        trainer_step: g.i64(0, 50) as u64,
        bus_version: g.i64(0, 50) as u64,
        bus_publishes: g.i64(0, 50) as u64,
        slot_fronts: (0..g.usize(0, 4)).map(|_| g.i64(0, 50) as u64).collect(),
        store,
        mem_device_used: g.i64(0, 1 << 30) as u64,
        mem_host_used: g.i64(0, 1 << 30) as u64,
        nodes,
    }
}

fn any_record(g: &mut Gen) -> JournalRecord {
    match g.usize(0, 11) {
        0 => JournalRecord::Meta {
            config: Value::object(vec![
                ("mode", Value::str("sync")),
                ("max_steps", Value::num(g.i64(1, 50) as f64)),
            ]),
        },
        1 => JournalRecord::Event {
            t_us: g.f64(0.0, 1e7),
            track: format!("track{}", g.usize(0, 3)),
            ph: (*g.choice(&["B", "E", "i", "C"])).to_string(),
            name: (*g.choice(&["generate", "train", "node_start"])).to_string(),
            value: g.f64(-5.0, 5.0),
        },
        2 => JournalRecord::Admit {
            rows: (0..g.usize(1, 3))
                .map(|i| (g.i64(0, 500) as u64 * 4 + i as u64, any_traj(g)))
                .collect(),
        },
        3 => JournalRecord::Consume {
            store_seqs: (0..g.usize(1, 4)).map(|_| g.i64(0, 500) as u64).collect(),
            reason: *g.choice(&[
                ConsumeReason::Sample,
                ConsumeReason::Evict,
                ConsumeReason::Stale,
            ]),
        },
        4 => JournalRecord::Mint {
            version: g.i64(0, 100) as u64,
            publisher: g.usize(0, 4),
        },
        5 => JournalRecord::Step { record: any_step(g) },
        6 => JournalRecord::Tick {
            step: g.i64(1, 50) as u64,
            tokens: g.i64(0, 100_000) as u64,
            trajectories: g.i64(0, 1000) as u64,
            chunks: g.i64(0, 1000) as u64,
        },
        7 => JournalRecord::Node {
            name: format!("reward{}", g.usize(0, 3)),
            state: if g.bool() { "start" } else { "stop" }.to_string(),
        },
        8 => JournalRecord::Snapshot(any_snapshot(g)),
        9 => JournalRecord::NodeRestart {
            node: format!("generator-{}", g.usize(0, 3)),
            attempt: g.i64(1, 5) as u64,
            backoff_ms: g.i64(1, 500) as u64,
            migrated: g.i64(0, 8) as u64,
            error: (*g.choice(&[
                "injected failure after 2 chunks",
                "reward executor panicked",
            ]))
            .to_string(),
        },
        10 => JournalRecord::FleetResize {
            node: "generator".to_string(),
            from: g.i64(1, 4) as u64,
            to: g.i64(1, 6) as u64,
            reason: if g.bool() { "queue low" } else { "queue drained" }.to_string(),
        },
        _ => JournalRecord::Finish {
            steps: g.i64(0, 50) as u64,
            trajectories: g.i64(0, 500) as u64,
        },
    }
}

// ---------------------------------------------------------------------------
// Wire-form properties

#[test]
fn prop_wire_form_fixpoint() {
    run_prop("journal_wire_fixpoint", 150, |g| {
        let rec = any_record(g);
        let seq = g.i64(0, 10_000) as u64;
        let s1 = rec.to_value(seq).to_string();
        let v = Value::parse(&s1).expect("journal line must parse");
        let (seq2, rec2) = JournalRecord::from_value(&v).expect("journal line must decode");
        assert_eq!(seq2, seq);
        assert_eq!(rec2.kind(), rec.kind());
        // write → parse → decode → write must be a fixpoint, which makes
        // every numeric payload exact across a journal round trip
        assert_eq!(rec2.to_value(seq).to_string(), s1);
    });
}

#[test]
fn prop_trajectory_round_trip_is_bit_exact() {
    run_prop("trajectory_round_trip", 200, |g| {
        let t = any_traj(g);
        let v = Value::parse(&trajectory_to_value(&t).to_string()).unwrap();
        let t2 = trajectory_from_value(&v).unwrap();
        assert_eq!(t.group_id, t2.group_id);
        assert_eq!(t.replica, t2.replica);
        assert_eq!(t.n_replicas, t2.n_replicas);
        assert_eq!(t.problem.prompt, t2.problem.prompt);
        assert_eq!(t.problem.answer, t2.problem.answer);
        assert_eq!(t.problem.difficulty, t2.problem.difficulty);
        assert_eq!(t.prompt_tokens, t2.prompt_tokens);
        assert_eq!(t.response_tokens, t2.response_tokens);
        assert_eq!(t.gen_version, t2.gen_version);
        assert_eq!(t.chunks, t2.chunks);
        assert_eq!(t.finish, t2.finish);
        assert_eq!(t.reward.to_bits(), t2.reward.to_bits());
        assert_eq!(t.advantage.to_bits(), t2.advantage.to_bits());
        assert_eq!(t.behavior_logp.len(), t2.behavior_logp.len());
        for (a, b) in t.behavior_logp.iter().zip(&t2.behavior_logp) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 logp must survive the f64 wire");
        }
    });
}

// ---------------------------------------------------------------------------
// Streaming reader: random mixes, mid-record truncation, empty file,
// interior corruption

#[test]
fn prop_streaming_reader_tolerates_torn_tail() {
    let path = tmp("prop_truncation.jsonl");
    run_prop("journal_reader_truncation", 80, |g| {
        let n = g.usize(2, 10);
        let recs: Vec<JournalRecord> = (0..n).map(|_| any_record(g)).collect();
        let lines: Vec<String> = recs
            .iter()
            .enumerate()
            .map(|(i, r)| r.to_value(i as u64).to_string())
            .collect();
        let full = lines.join("\n") + "\n";
        let last_start = full.len() - (lines.last().unwrap().len() + 1);
        // cut somewhere inside the final line (the wire form is ASCII, so
        // every byte offset is a char boundary)
        let cut = g.usize(last_start + 1, full.len() - 1);
        std::fs::write(&path, &full.as_bytes()[..cut]).unwrap();

        let mut reader = JournalReader::open(&path).unwrap();
        let mut got = 0usize;
        while let Some(item) = reader.next_record() {
            let (seq, rec) = item.expect("intact lines must decode");
            assert_eq!(seq as usize, got);
            assert_eq!(rec.kind(), recs[got].kind());
            got += 1;
        }
        if cut == full.len() - 1 {
            // only the trailing newline is gone: the final line is still
            // complete JSON and must decode, not count as torn
            assert_eq!(got, n);
            assert!(!reader.truncated_tail());
        } else {
            assert_eq!(got, n - 1, "torn final line must end the stream");
            assert!(reader.truncated_tail());
        }
    });
}

#[test]
fn reader_empty_file_is_a_clean_end() {
    let path = tmp("empty.jsonl");
    std::fs::write(&path, b"").unwrap();
    let mut r = JournalReader::open(&path).unwrap();
    assert!(r.next_record().is_none());
    assert!(!r.truncated_tail());
    assert_eq!(r.lines_read(), 0);
}

#[test]
fn reader_rejects_interior_corruption() {
    let path = tmp("corrupt.jsonl");
    let a = JournalRecord::Mint {
        version: 1,
        publisher: 0,
    }
    .to_value(0)
    .to_string();
    let b = JournalRecord::Mint {
        version: 2,
        publisher: 0,
    }
    .to_value(2)
    .to_string();
    std::fs::write(&path, format!("{a}\n{{torn garbage\n{b}\n")).unwrap();
    let mut r = JournalReader::open(&path).unwrap();
    assert!(r.next_record().unwrap().is_ok());
    let second = r.next_record().expect("corrupt interior line yields an item");
    assert!(second.is_err(), "interior corruption must be a hard error");
    assert!(r.next_record().is_none(), "the stream ends after the error");
}

#[test]
fn corruption_diagnostic_reports_the_physical_line_number() {
    let path = tmp("corrupt_line_no.jsonl");
    let good = JournalRecord::Mint {
        version: 1,
        publisher: 0,
    }
    .to_value(0)
    .to_string();
    // physical line 3 is the corrupt one (line 2 is blank)
    std::fs::write(&path, format!("{good}\n\n{{torn garbage\n{good}\n")).unwrap();
    let mut r = JournalReader::open(&path).unwrap();
    assert!(r.next_record().unwrap().is_ok());
    match r.next_record() {
        Some(Err(e)) => {
            let msg = e.to_string();
            assert!(msg.contains("line 3"), "wrong corruption site: {msg}");
        }
        other => panic!("expected a corruption error, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Writer reopen: the torn tail a SIGKILL leaves must be trimmed before
// the first appended record, or a tolerated torn *tail* becomes hard
// *interior* corruption and every later read/resume of the journal fails

#[test]
fn append_trims_the_torn_tail_so_a_resumed_journal_stays_readable() {
    let path = tmp("torn_append.jsonl");
    let mint = |version: u64, seq: u64| {
        JournalRecord::Mint {
            version,
            publisher: 0,
        }
        .to_value(seq)
        .to_string()
    };
    // two kill→resume cycles: the second exercises re-reading a journal
    // that was already resumed once from a torn tail
    let mut expect_versions = vec![1u64, 2];
    for cycle in 0..2u64 {
        let mut full = String::new();
        for (i, v) in expect_versions.iter().enumerate() {
            full.push_str(&mint(*v, i as u64));
            full.push('\n');
        }
        // the SIGKILL tears the final line mid-record
        full.push_str(&mint(90 + cycle, expect_versions.len() as u64));
        std::fs::write(&path, &full.as_bytes()[..full.len() - 4]).unwrap();

        let appended_version = 10 + cycle;
        let w = JournalWriter::append(&path, expect_versions.len() as u64).unwrap();
        w.write(&JournalRecord::Mint {
            version: appended_version,
            publisher: 0,
        })
        .unwrap();
        drop(w);
        expect_versions.push(appended_version);

        let mut r = JournalReader::open(&path).unwrap();
        let mut got = Vec::new();
        while let Some(item) = r.next_record() {
            let (seq, rec) = item.expect("resumed journal must have no interior corruption");
            assert_eq!(seq, got.len() as u64, "seq stream stays contiguous");
            match rec {
                JournalRecord::Mint { version, .. } => got.push(version),
                other => panic!("unexpected record {other:?}"),
            }
        }
        assert!(!r.truncated_tail(), "the trimmed+appended tail is clean");
        assert_eq!(got, expect_versions, "cycle {cycle}");
    }
}

#[test]
fn append_truncates_a_journal_with_no_complete_line_to_empty() {
    let path = tmp("torn_append_empty.jsonl");
    std::fs::write(&path, b"{\"seq\":0,\"kind\":\"mi").unwrap();
    let w = JournalWriter::append(&path, 0).unwrap();
    w.write(&JournalRecord::Mint {
        version: 7,
        publisher: 0,
    })
    .unwrap();
    drop(w);
    let recs: Vec<_> = JournalReader::open(&path)
        .unwrap()
        .map(|r| r.expect("journal must be readable"))
        .collect();
    assert_eq!(recs.len(), 1);
    assert_eq!(recs[0].0, 0);
}

// ---------------------------------------------------------------------------
// Resume planning over a synthetic journal

fn traj_fixed(group_id: u64) -> Trajectory {
    Trajectory {
        group_id,
        replica: 0,
        n_replicas: 1,
        problem: Problem {
            prompt: "1+1=".into(),
            answer: "2".into(),
            difficulty: Difficulty::Add1,
        },
        prompt_tokens: vec![1],
        response_tokens: vec![2],
        behavior_logp: vec![-0.5],
        gen_version: 1,
        chunks: 1,
        finish: FinishReason::Eos,
        reward: 0.0,
        advantage: 0.0,
    }
}

#[test]
fn plan_resume_folds_suffix_onto_latest_snapshot() {
    let path = tmp("plan_resume.jsonl");
    let records = vec![
        JournalRecord::Meta {
            config: Value::object(vec![
                ("mode", Value::str("async_buffered")),
                ("max_steps", Value::num(8.0)),
            ]),
        },
        JournalRecord::Admit {
            rows: vec![(0, traj_fixed(0)), (1, traj_fixed(1))],
        },
        JournalRecord::Mint {
            version: 1,
            publisher: 0,
        },
        JournalRecord::Snapshot(SnapshotRecord {
            trainer_step: 1,
            bus_version: 1,
            store: Some(StoreSnapshot {
                next_seq: 2,
                watermark: 1,
                rows: vec![(1, traj_fixed(1))],
                partials: Vec::new(),
            }),
            ..SnapshotRecord::default()
        }),
        // seq 1 races the cut: journaled again after the snapshot that
        // already contains it — resume must dedup by admission seq
        JournalRecord::Admit {
            rows: vec![(1, traj_fixed(1)), (2, traj_fixed(2))],
        },
        JournalRecord::Consume {
            store_seqs: vec![1],
            reason: ConsumeReason::Sample,
        },
        JournalRecord::Step {
            record: TrainStepRecord {
                step: 1,
                ..TrainStepRecord::default()
            },
        },
        JournalRecord::Step {
            record: TrainStepRecord {
                step: 2,
                ..TrainStepRecord::default()
            },
        },
        JournalRecord::Tick {
            step: 2,
            tokens: 100,
            trajectories: 8,
            chunks: 4,
        },
        JournalRecord::Mint {
            version: 2,
            publisher: 0,
        },
    ];
    let mut text = String::new();
    for (i, r) in records.iter().enumerate() {
        text.push_str(&r.to_value(i as u64).to_string());
        text.push('\n');
    }
    std::fs::write(&path, &text).unwrap();

    let plan = plan_resume(&path).unwrap();
    assert!(!plan.finished);
    assert!(!plan.truncated_tail);
    assert_eq!(plan.config.req_str("mode").unwrap(), "async_buffered");
    let st = plan.state;
    assert_eq!(st.start_step, 2, "start step is the last journaled step");
    assert_eq!(st.bus_version, 2, "bus front is the max minted version");
    assert_eq!(st.next_seq, records.len() as u64);
    assert_eq!(st.prior.tokens, 100);
    assert_eq!(st.prior.trajectories, 8);
    assert_eq!(st.prior.chunks, 4);
    assert_eq!(st.prior.records.len(), 2);
    let store = st.store.expect("buffered journal reconstructs a store");
    // snapshot {1} + suffix admits {1 (dup), 2} - consumed {1} = {2}
    let seqs: Vec<u64> = store.rows.iter().map(|(s, _)| *s).collect();
    assert_eq!(seqs, vec![2]);
    assert_eq!(store.next_seq, 3);
    assert_eq!(store.watermark, 2, "watermark advances to the resume step");

    // with a finish marker appended the same journal becomes a no-op plan
    text.push_str(
        &JournalRecord::Finish {
            steps: 8,
            trajectories: 32,
        }
        .to_value(records.len() as u64)
        .to_string(),
    );
    text.push('\n');
    std::fs::write(&path, &text).unwrap();
    assert!(plan_resume(&path).unwrap().finished);
}

/// The newest suffix admissions were all consumed: the resumed store must
/// still mint fresh seqs *above* them — re-minting a journaled store_seq
/// would poison the next resume's dedup-by-seq and shared consumed set.
#[test]
fn plan_resume_advances_next_seq_past_consumed_suffix_admissions() {
    let path = tmp("plan_resume_consumed_suffix.jsonl");
    let records = vec![
        JournalRecord::Meta {
            config: Value::object(vec![("mode", Value::str("async_buffered"))]),
        },
        JournalRecord::Snapshot(SnapshotRecord {
            store: Some(StoreSnapshot {
                next_seq: 5,
                watermark: 0,
                rows: Vec::new(),
                partials: Vec::new(),
            }),
            ..SnapshotRecord::default()
        }),
        JournalRecord::Admit {
            rows: vec![(5, traj_fixed(5)), (6, traj_fixed(6))],
        },
        JournalRecord::Consume {
            store_seqs: vec![5, 6],
            reason: ConsumeReason::Sample,
        },
    ];
    let mut text = String::new();
    for (i, r) in records.iter().enumerate() {
        text.push_str(&r.to_value(i as u64).to_string());
        text.push('\n');
    }
    std::fs::write(&path, &text).unwrap();

    let store = plan_resume(&path).unwrap().state.store.unwrap();
    assert!(store.rows.is_empty(), "everything admitted was consumed");
    assert_eq!(
        store.next_seq, 7,
        "next_seq must clear the consumed admissions, not fall back to the snapshot's"
    );
}

#[test]
fn plan_resume_requires_a_meta_record() {
    let path = tmp("no_meta.jsonl");
    let line = JournalRecord::Mint {
        version: 1,
        publisher: 0,
    }
    .to_value(0)
    .to_string();
    std::fs::write(&path, format!("{line}\n")).unwrap();
    assert!(plan_resume(&path).is_err());
}

// ---------------------------------------------------------------------------
// Forward tolerance: well-formed records with a kind this build does not
// know (a journal written by a newer build) must decode as skippable
// markers, not poison the read — and resume must ignore them entirely

#[test]
fn unknown_kind_decodes_as_a_skippable_marker_and_keeps_its_tag() {
    let line = r#"{"seq":5,"kind":"quantum_checkpoint","payload":[1,2,3]}"#;
    let v = Value::parse(line).unwrap();
    let (seq, rec) = JournalRecord::from_value(&v)
        .expect("an unrecognized kind must not be a decode error");
    assert_eq!(seq, 5);
    assert_eq!(rec.kind(), "unknown");
    // the payload is dropped but the ORIGINAL tag survives a re-write, so
    // copying a journal through this build does not relabel newer records
    let rewritten = rec.to_value(5).to_string();
    assert!(
        rewritten.contains(r#""kind":"quantum_checkpoint""#),
        "re-serialized form lost the original tag: {rewritten}"
    );
    let (_, again) = JournalRecord::from_value(&Value::parse(&rewritten).unwrap()).unwrap();
    assert_eq!(again.kind(), "unknown");
    // malformed lines are still corruption — tolerance is for the TAG,
    // not for broken JSON
    assert!(Value::parse("{torn garbage").is_err());
}

#[test]
fn reader_streams_past_unknown_kinds() {
    let path = tmp("unknown_kinds.jsonl");
    let mint = JournalRecord::Mint {
        version: 3,
        publisher: 0,
    }
    .to_value(0)
    .to_string();
    std::fs::write(
        &path,
        format!("{mint}\n{{\"seq\":1,\"kind\":\"hologram\",\"x\":9}}\n"),
    )
    .unwrap();
    let recs: Vec<_> = JournalReader::open(&path)
        .unwrap()
        .map(|r| r.expect("unknown kinds must stream, not error"))
        .collect();
    assert_eq!(recs.len(), 2);
    assert_eq!(recs[1].1.kind(), "unknown");
}

#[test]
fn plan_resume_ignores_restart_resize_and_unknown_records() {
    // two journals that differ only by churn + unknown records must plan
    // to the identical resume state
    let core = vec![
        JournalRecord::Meta {
            config: Value::object(vec![("mode", Value::str("async_buffered"))]),
        },
        JournalRecord::Admit {
            rows: vec![(0, traj_fixed(0)), (1, traj_fixed(1))],
        },
        JournalRecord::Mint {
            version: 1,
            publisher: 0,
        },
        JournalRecord::Step {
            record: TrainStepRecord {
                step: 1,
                ..TrainStepRecord::default()
            },
        },
    ];
    let churn = vec![
        JournalRecord::NodeRestart {
            node: "generator-0".into(),
            attempt: 1,
            backoff_ms: 50,
            migrated: 2,
            error: "injected failure after 1 chunks".into(),
        },
        JournalRecord::FleetResize {
            node: "generator".into(),
            from: 2,
            to: 3,
            reason: "queue low".into(),
        },
        JournalRecord::Unknown {
            kind: "from_the_future".into(),
        },
    ];

    let plain = tmp("resume_no_churn.jsonl");
    let mut text = String::new();
    for (i, r) in core.iter().enumerate() {
        text.push_str(&r.to_value(i as u64).to_string());
        text.push('\n');
    }
    std::fs::write(&plain, &text).unwrap();

    let churned = tmp("resume_with_churn.jsonl");
    let mut text = String::new();
    let mut seq = 0u64;
    for r in core.iter().take(2).chain(&churn).chain(core.iter().skip(2)) {
        text.push_str(&r.to_value(seq).to_string());
        text.push('\n');
        seq += 1;
    }
    std::fs::write(&churned, &text).unwrap();

    let a = plan_resume(&plain).unwrap().state;
    let b = plan_resume(&churned).unwrap().state;
    assert_eq!(a.start_step, b.start_step);
    assert_eq!(a.bus_version, b.bus_version);
    assert_eq!(a.prior.records.len(), b.prior.records.len());
    let rows = |st: &Option<StoreSnapshot>| -> Vec<u64> {
        st.as_ref()
            .map(|s| s.rows.iter().map(|(q, _)| *q).collect())
            .unwrap_or_default()
    };
    assert_eq!(rows(&a.store), rows(&b.store), "churn must not change the cut");
    // the churned journal is longer, so only next_seq may differ
    assert_eq!(b.next_seq, a.next_seq + churn.len() as u64);
}

// ---------------------------------------------------------------------------
// Artifact-gated end-to-end suites (skip gracefully without
// `make artifacts`, exactly like tests/integration.rs)

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/nano/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/nano missing (run `make artifacts`)");
    }
    ok
}

fn base_cfg(tag: &str) -> PipelineConfig {
    PipelineConfig {
        artifact_dir: "artifacts/nano".into(),
        mode: Mode::Sync,
        max_steps: 3,
        max_response: 10,
        n_generations: 4,
        seed: 23,
        checkpoint_every: 1,
        out_dir: std::env::temp_dir().join(format!("llamarl_journal_{tag}")),
        ..PipelineConfig::default()
    }
}

/// Kill-at-a-random-point: truncate a completed run's journal at random
/// byte offsets (what a SIGKILL leaves behind), resume each cut in place,
/// and require the merged run to reach the reference trajectory count.
#[test]
fn kill_and_resume_reaches_reference_trajectory_count() {
    if !have_artifacts() {
        return;
    }
    let ref_cfg = base_cfg("resume_ref");
    let reference = run_training(&ref_cfg).unwrap();
    assert_eq!(reference.records.len() as u64, ref_cfg.max_steps);
    let rows = reference.trajectories / reference.steps;

    let victim_cfg = base_cfg("resume_victim");
    run_training(&victim_cfg).unwrap();
    let journal = victim_cfg.out_dir.join("journal.jsonl");
    let full = std::fs::read(&journal).unwrap();
    let meta_end = full.iter().position(|b| *b == b'\n').unwrap() + 1;

    let mut rng = Rng::new(42);
    for _case in 0..4 {
        // keep the meta record, cut anywhere after it (ASCII stream)
        let cut = rng.range_usize(meta_end, full.len());
        std::fs::write(&journal, &full[..cut]).unwrap();

        let plan = plan_resume(&journal).unwrap();
        if plan.finished {
            continue; // cut landed past the finish record's payload
        }
        let mut cfg = PipelineConfig::default();
        config::apply_json(&mut cfg, &plan.config).unwrap();
        let mut state = plan.state;
        if state.start_step >= cfg.max_steps {
            // killed between the last step record and the finish marker:
            // every step is already durable, nothing to re-drive
            assert_eq!(state.start_step * rows, reference.trajectories);
            continue;
        }
        if let Some((_ck, packed)) = find_checkpoint_state(&cfg.out_dir, state.start_step) {
            state.init_state = Some(packed);
        }
        cfg.resume = Some(state);
        let resumed = run_training(&cfg).unwrap();
        assert_eq!(resumed.steps, reference.steps, "kill at byte {cut}");
        assert_eq!(resumed.records.len(), reference.records.len());
        assert_eq!(
            resumed.trajectories, reference.trajectories,
            "count parity after kill at byte {cut}"
        );

        // the resumed journal must remain one readable document: append
        // trimmed the torn tail, so a full re-read sees no interior
        // corruption and a second resume of the same journal still works
        let mut reader = JournalReader::open(&journal).unwrap();
        while let Some(item) = reader.next_record() {
            item.expect("journal must stay readable after a torn-tail resume");
        }
        assert!(!reader.truncated_tail());
        assert!(
            plan_resume(&journal).unwrap().finished,
            "re-planning the completed resumed journal finds its finish marker"
        );
    }
}

/// Deterministic replay: re-drive the recorded config from scratch and
/// require every journaled step record to match the live run bit-for-bit
/// (sync mode is single-threaded and seeded, so this is exact).
#[test]
fn replay_reproduces_sync_trajectory_bit_for_bit() {
    if !have_artifacts() {
        return;
    }
    let rec_cfg = base_cfg("replay_rec");
    let recorded_report = run_training(&rec_cfg).unwrap();
    let plan = plan_resume(rec_cfg.out_dir.join("journal.jsonl")).unwrap();
    assert!(plan.finished, "a clean run must journal its finish marker");
    let recorded = plan.state.prior.records;
    assert_eq!(recorded.len(), recorded_report.records.len());

    let mut cfg = PipelineConfig::default();
    config::apply_json(&mut cfg, &plan.config).unwrap();
    cfg.out_dir = std::env::temp_dir().join("llamarl_journal_replay_out");
    let live = run_training(&cfg).unwrap();
    let mismatches = compare_steps(&recorded, &live.records);
    assert!(
        mismatches.is_empty(),
        "replay diverged at step {} field {}: recorded {} vs live {}",
        mismatches[0].step,
        mismatches[0].field,
        mismatches[0].recorded,
        mismatches[0].live
    );
}
