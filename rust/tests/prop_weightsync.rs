//! Property tests for the sharded weight-sync plane: layout covers,
//! resharding-plan exactness, transfer fidelity (f32 exact, int8 within the
//! quantizer's bound), and double-buffer version fencing under concurrency.

use std::sync::Arc;

use llamarl::model::{int8_error_bound, VersionedParams};
use llamarl::util::prop::{run_prop, Gen};
use llamarl::weightsync::{
    contiguous_entries, encode_shard, plan_reshard, run_transfer, run_transfer_delta,
    GeneratorSlot, Layout, ReshardPlan, ShardEncoding,
};

fn random_layout_pair(g: &mut Gen) -> (Layout, Layout, usize) {
    let hint = g.size(8, 400);
    let n_tensors = g.usize(1, 6);
    let sizes: Vec<usize> =
        (0..n_tensors).map(|_| g.usize(1, (hint / n_tensors).max(2))).collect();
    let entries = contiguous_entries(&sizes);
    let n: usize = sizes.iter().sum();
    let src = Layout::fsdp(n, g.usize(1, 8));
    let dst = if g.bool() {
        Layout::tp(n, g.usize(1, 6), &entries).unwrap()
    } else {
        Layout::tp_flat(n, g.usize(1, 6))
    };
    (src, dst, n)
}

/// Every element must arrive exactly once, from the rank that owns it in
/// `src`, at the rank that owns it in `dst`.
fn assert_plan_exact(plan: &ReshardPlan, src: &Layout, dst: &Layout, n: usize) {
    let mut delivered = vec![0u32; n];
    for op in &plan.ops {
        let src_owner = src
            .shards
            .iter()
            .find(|s| s.start <= op.start && op.end() <= s.end())
            .unwrap_or_else(|| panic!("op {op:?} spans source shards"));
        assert_eq!(src_owner.rank, op.src);
        let dst_owner = dst
            .shards
            .iter()
            .find(|s| s.start <= op.start && op.end() <= s.end())
            .unwrap_or_else(|| panic!("op {op:?} spans destination shards"));
        assert_eq!(dst_owner.rank, op.dst);
        for d in &mut delivered[op.start..op.end()] {
            *d += 1;
        }
    }
    assert!(
        delivered.iter().all(|d| *d == 1),
        "some element delivered != once"
    );
}

#[test]
fn layouts_always_cover_disjointly() {
    run_prop("layout_cover", 200, |g| {
        let (src, dst, _) = random_layout_pair(g);
        src.validate().unwrap();
        dst.validate().unwrap();
        let owned: usize = (0..src.n_ranks).map(|r| src.rank_elems(r)).sum();
        assert_eq!(owned, src.num_params);
    });
}

#[test]
fn plan_delivers_every_element_exactly_once() {
    run_prop("plan_exact", 200, |g| {
        let (src, dst, n) = random_layout_pair(g);
        let plan = plan_reshard(&src, &dst).unwrap();
        assert_plan_exact(&plan, &src, &dst, n);
        assert!(plan.max_link_elems() <= plan.total_elems());
    });
}

#[test]
fn f32_transfer_reconstructs_exactly() {
    run_prop("transfer_f32_exact", 100, |g| {
        let (src, dst, n) = random_layout_pair(g);
        let plan = plan_reshard(&src, &dst).unwrap();
        let params: Vec<f32> = (0..n).map(|_| g.f64(-10.0, 10.0) as f32).collect();
        let mut out = vec![f32::NAN; n];
        let t = run_transfer(&params, &mut out, &plan, 1, ShardEncoding::F32);
        assert_eq!(out, params);
        assert_eq!(t.bytes, n * 4);
    });
}

#[test]
fn int8_transfer_stays_within_quant_bound() {
    run_prop("transfer_int8_bound", 100, |g| {
        let (src, dst, n) = random_layout_pair(g);
        let plan = plan_reshard(&src, &dst).unwrap();
        let mag = 10f64.powf(g.f64(-4.0, 4.0)) as f32;
        let params: Vec<f32> = (0..n).map(|_| g.f64(-1.0, 1.0) as f32 * mag).collect();
        let mut out = vec![0.0f32; n];
        let t = run_transfer(&params, &mut out, &plan, 1, ShardEncoding::Int8);
        // the timing record's own bound bookkeeping must hold...
        assert!(
            t.max_abs_err <= t.err_bound,
            "recorded err {} > recorded bound {}",
            t.max_abs_err,
            t.err_bound
        );
        // ...and so must the per-element bound derived from the worst shard
        let maxabs = params.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let bound = int8_error_bound(maxabs);
        for (a, b) in params.iter().zip(&out) {
            assert!((a - b).abs() <= bound, "err {} > bound {bound}", (a - b).abs());
        }
        // int8 payloads are strictly smaller than f32 for non-trivial sizes
        if n > 8 * plan.ops.len() {
            assert!(t.bytes < n * 4);
        }
    });
}

/// Build a new vector from `base` with roughly `sparsity` of the elements
/// changed (always at least one when n > 0); returns the new vector.
fn perturb(g: &mut Gen, base: &[f32], sparsity: f64) -> Vec<f32> {
    let mut new = base.to_vec();
    let mut changed = 0usize;
    for x in new.iter_mut() {
        if g.rng.f64() < sparsity {
            *x += g.f64(-2.0, 2.0) as f32;
            changed += 1;
        }
    }
    if changed == 0 && !new.is_empty() {
        new[0] += 1.0;
    }
    new
}

#[test]
fn delta_transfer_roundtrips_bit_exactly_across_sparsity() {
    run_prop("transfer_delta_exact", 120, |g| {
        let (src, dst, n) = random_layout_pair(g);
        let plan = plan_reshard(&src, &dst).unwrap();
        let base: Vec<f32> = (0..n).map(|_| g.f64(-5.0, 5.0) as f32).collect();
        // sweep density from ~0.1% to 100%: both the sparse index+value and
        // the dense XOR packings must reconstruct bit-exactly
        let sparsity = 10f64.powf(g.f64(-3.0, 0.0));
        let new = perturb(g, &base, sparsity);
        let mut out = base.clone();
        let t = run_transfer_delta(&new, &base, &mut out, &plan, 7, 8, None);
        assert!(
            out.iter().zip(&new).all(|(a, b)| a.to_bits() == b.to_bits()),
            "delta reconstruction not bit-exact at sparsity {sparsity}"
        );
        assert_eq!(t.max_abs_err, 0.0);
        assert_eq!(t.err_bound, 0.0);
        // an exact delta never costs more wire than the full transfer
        assert!(t.bytes <= n * 4, "delta bytes {} > full {}", t.bytes, n * 4);
    });
}

#[test]
fn rle_delta_roundtrips_bit_exactly_across_clustered_densities() {
    use llamarl::weightsync::{apply_packet, encode_shard_delta, ShardPayload, TransferOp};
    run_prop("transfer_delta_rle", 150, |g| {
        // one contiguous changed block covering 50%..~100% of the op: past
        // the sparse break-even, where the RLE-vs-dense choice lives
        let n = g.size(32, 600).max(32);
        let frac = g.f64(0.5, 1.0);
        // ceil + div_ceil floor keep the changed density at or above the
        // sparse break-even, so the encoder is always in RLE-vs-dense land
        let changed = ((n as f64 * frac).ceil() as usize).clamp(n.div_ceil(2), n);
        let start = g.usize(0, n - changed);
        let base: Vec<f32> = (0..n).map(|_| g.f64(-5.0, 5.0) as f32).collect();
        let mut new = base.clone();
        for x in new.iter_mut().skip(start).take(changed) {
            *x += 1.0 + g.f64(0.0, 1.0) as f32;
        }
        let op = TransferOp { src: 0, dst: 0, start: 0, len: n };
        let (pkt, bound) = encode_shard_delta(&new, &base, 4, 5, op, None);
        assert_eq!(bound, 0.0);
        // never more wire than raw dense XOR; strictly less whenever the
        // zero runs outweigh the two-counter-per-run overhead
        assert!(pkt.payload_bytes() <= n * 4);
        if changed + 4 < n {
            assert!(
                matches!(pkt.payload, ShardPayload::RleDelta { .. }),
                "clustered block of {changed}/{n} must zero-run encode"
            );
            assert!(pkt.payload_bytes() < n * 4);
        }
        let mut out = base.clone();
        apply_packet(&mut out, &pkt);
        assert!(
            out.iter().zip(&new).all(|(a, b)| a.to_bits() == b.to_bits()),
            "RLE reconstruction not bit-exact ({changed}/{n} changed at {start})"
        );
    });
}

#[test]
fn topk_transfer_error_within_bound_across_sparsity() {
    run_prop("transfer_topk_bound", 120, |g| {
        let (src, dst, n) = random_layout_pair(g);
        let plan = plan_reshard(&src, &dst).unwrap();
        let base: Vec<f32> = (0..n).map(|_| g.f64(-5.0, 5.0) as f32).collect();
        let sparsity = 10f64.powf(g.f64(-3.0, 0.0));
        let new = perturb(g, &base, sparsity);
        let frac = 10f64.powf(g.f64(-2.0, 0.0)); // kept fraction 1%..100%
        let mut out = base.clone();
        let t = run_transfer_delta(&new, &base, &mut out, &plan, 7, 8, Some(frac));
        // the reported bound (largest dropped |update| across shards) must
        // dominate the realized reconstruction error
        assert!(
            t.max_abs_err <= t.err_bound,
            "topk err {} > bound {} (sparsity {sparsity}, frac {frac})",
            t.max_abs_err,
            t.err_bound
        );
        // kept updates apply exactly: every output element is either the
        // base value (dropped) or the new value (kept), bitwise
        for ((o, b), a) in out.iter().zip(&base).zip(&new) {
            assert!(
                o.to_bits() == b.to_bits() || o.to_bits() == a.to_bits(),
                "output element is neither base nor new"
            );
        }
        // when the cap does not bind, top-k degenerates to the exact delta
        if t.err_bound == 0.0 {
            assert_eq!(t.max_abs_err, 0.0);
            assert!(out.iter().zip(&new).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    });
}

#[test]
fn fenced_swap_never_exposes_partial_or_stale_versions() {
    run_prop("swap_fencing", 30, |g| {
        let n = g.size(16, 256).max(16);
        let versions = 20u64;
        let plan = plan_reshard(&Layout::fsdp(n, 4), &Layout::tp_flat(n, 2)).unwrap();
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; n])));
        let publisher = {
            let slot = slot.clone();
            let plan = plan.clone();
            std::thread::spawn(move || {
                for v in 1..=versions {
                    let data = vec![v as f32; n];
                    slot.begin(v, plan.ops.len());
                    for &op in &plan.ops {
                        slot.recv(&encode_shard(&data, v, op, ShardEncoding::F32));
                    }
                }
            })
        };
        // Decode loop: attach + fenced swap. Every observed front must be
        // internally consistent (all elements equal its version) and
        // versions must never go backwards.
        let mut last = 0u64;
        for _ in 0..2000 {
            if let Some(snap) = slot.swap_at_boundary() {
                assert!(snap.version > last, "swap went backwards");
                last = snap.version;
            }
            let front = slot.attach();
            assert!(
                front.data.iter().all(|x| *x == front.version as f32),
                "torn front buffer at version {}",
                front.version
            );
            assert!(front.version >= last);
        }
        publisher.join().unwrap();
        // drain whatever is still staged; the final front must be complete
        while slot.swap_at_boundary().is_some() {}
        let front = slot.attach();
        assert!(front.data.iter().all(|x| *x == front.version as f32));
    });
}
