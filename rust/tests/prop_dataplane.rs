//! Property tests on the trajectory data plane, most importantly the two
//! hard invariants of the RolloutStore:
//!
//! 1. **occupancy never exceeds capacity** — under any admission policy,
//!    any interleaving of concurrent producers and a sampler;
//! 2. **a sampled row's lag never exceeds the max-staleness bound** — the
//!    trainer can never be handed data older than configured, no matter
//!    how the watermark races admissions.
//!
//! (Hand-rolled harness in util::prop — proptest is not in the offline
//! vendor set.)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use llamarl::data::{Difficulty, Problem};
use llamarl::dataplane::{
    run_driver, AdmissionPolicy, DriverConfig, RolloutStore, SamplingStrategy, StoreConfig,
    Transport,
};
use llamarl::rl::{FinishReason, Trajectory};
use llamarl::util::prop::{run_prop, Gen};

fn traj(group_id: u64, gen_version: u64) -> Trajectory {
    Trajectory {
        group_id,
        replica: 0,
        n_replicas: 1,
        problem: Problem {
            prompt: "1+1=".into(),
            answer: "2".into(),
            difficulty: Difficulty::Add1,
        },
        prompt_tokens: vec![1],
        response_tokens: vec![2],
        behavior_logp: vec![-0.5],
        gen_version,
        chunks: 1,
        finish: FinishReason::Eos,
        reward: 0.0,
        advantage: 0.0,
    }
}

fn any_admission(g: &mut Gen) -> AdmissionPolicy {
    *g.choice(&[
        AdmissionPolicy::Block,
        AdmissionPolicy::DropNewest,
        AdmissionPolicy::EvictOldest,
    ])
}

fn any_sampling(g: &mut Gen) -> SamplingStrategy {
    *g.choice(&[
        SamplingStrategy::Fifo,
        SamplingStrategy::FreshestFirst,
        SamplingStrategy::StalenessWeighted,
    ])
}

#[test]
fn occupancy_never_exceeds_capacity_under_concurrency() {
    run_prop("dp_capacity", 25, |g| {
        let capacity = g.usize(1, 24);
        let cfg = StoreConfig {
            capacity,
            shards: g.usize(1, 5),
            max_staleness: if g.bool() { Some(g.i64(0, 6) as u64) } else { None },
            // Block would deadlock without a steady consumer; the capacity
            // invariant for it is covered by the driver test below
            admission: *g.choice(&[AdmissionPolicy::DropNewest, AdmissionPolicy::EvictOldest]),
            sampling: any_sampling(g),
            seed: g.i64(0, 1 << 30) as u64,
        };
        let store = Arc::new(RolloutStore::new(cfg));
        let peak = Arc::new(AtomicUsize::new(0));
        let n_producers = g.usize(1, 4);
        let per = g.usize(5, 40);
        let group_rows = g.usize(1, 6);
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let store = store.clone();
            let peak = peak.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let gid = (p * 10_000 + i) as u64;
                    let group = (0..group_rows)
                        .map(|r| traj(gid, (i % 7) as u64 + r as u64))
                        .collect();
                    store.push_group(group).unwrap();
                    peak.fetch_max(store.occupancy(), Ordering::Relaxed);
                }
            }));
        }
        // a racing sampler + watermark mover
        let sampler = {
            let store = store.clone();
            let peak = peak.clone();
            std::thread::spawn(move || {
                for v in 0..30u64 {
                    store.advance_watermark(v / 3);
                    let _ = store.sample(3, Duration::from_millis(1));
                    peak.fetch_max(store.occupancy(), Ordering::Relaxed);
                }
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        sampler.join().unwrap();
        let peak = peak.load(Ordering::Relaxed).max(store.occupancy());
        assert!(
            peak <= capacity,
            "occupancy {peak} exceeded capacity {capacity}"
        );
        // accounting closes: every admitted row is exactly one of resident,
        // sampled, evicted, or aged-out-in-place. dropped_stale mixes
        // admission-time rejections (never admitted) with in-place aging
        // (admitted), so the residual is bounded by it rather than equal.
        let s = store.snapshot();
        let accounted = s.sampled + s.evicted + store.occupancy() as u64;
        assert!(
            s.admitted >= accounted && s.admitted - accounted <= s.dropped_stale,
            "row accounting must close: {s:?}"
        );
    });
}

#[test]
fn sampled_lag_never_exceeds_staleness_bound() {
    run_prop("dp_staleness", 40, |g| {
        let bound = g.i64(0, 5) as u64;
        let cfg = StoreConfig {
            capacity: g.usize(4, 32),
            shards: g.usize(1, 4),
            max_staleness: Some(bound),
            admission: any_admission(g),
            sampling: any_sampling(g),
            seed: g.i64(0, 1 << 30) as u64,
        };
        let store = RolloutStore::new(cfg);
        let mut max_seen_lag = 0u64;
        let mut watermark = 0u64;
        for round in 0..12u64 {
            // admit rows with versions scattered around the watermark
            for k in 0..g.usize(1, 5) {
                let v = watermark.saturating_sub(g.i64(0, 8) as u64);
                let _ = store.push_group(vec![traj(round * 100 + k as u64, v)]);
            }
            if g.bool() {
                watermark += g.i64(0, 3) as u64;
                store.advance_watermark(watermark);
            }
            for t in store.sample(g.usize(1, 6), Duration::from_millis(1)).unwrap() {
                max_seen_lag = max_seen_lag.max(watermark.saturating_sub(t.gen_version));
            }
        }
        assert!(
            max_seen_lag <= bound,
            "consumed lag {max_seen_lag} exceeds bound {bound}"
        );
        let snap = store.snapshot();
        assert!(
            snap.max_sampled_lag <= bound,
            "store-recorded lag {} exceeds bound {bound}",
            snap.max_sampled_lag
        );
    });
}

#[test]
fn block_admission_capacity_holds_with_live_consumer() {
    // the Block policy needs a consumer thread; drive it end to end and
    // check the capacity invariant via the store's own peak counter
    let r = run_driver(&DriverConfig {
        transport: Transport::Store(StoreConfig {
            capacity: 8,
            shards: 2,
            max_staleness: Some(3),
            admission: AdmissionPolicy::Block,
            sampling: SamplingStrategy::Fifo,
            seed: 11,
        }),
        producers: 3,
        group_rows: 3,
        train_steps: 15,
        rows_per_step: 4,
        gen_group_micros: 150,
        gen_sigma: 0.8,
        train_step_micros: 400,
        seed: 11,
    });
    let dp = r.dataplane.expect("store telemetry");
    assert!(dp.peak_occupancy <= 8, "peak {} > capacity", dp.peak_occupancy);
    assert!(dp.max_sampled_lag <= 3, "lag {} > bound", dp.max_sampled_lag);
    assert_eq!(r.steps, 15);
}

#[test]
fn sampling_strategies_return_identical_multisets() {
    run_prop("dp_strategies", 30, |g| {
        let n = g.usize(2, 12);
        let mk = |sampling| {
            let store = RolloutStore::new(StoreConfig {
                capacity: 64,
                shards: 3,
                max_staleness: None,
                admission: AdmissionPolicy::EvictOldest,
                sampling,
                seed: 5,
            });
            for i in 0..n {
                store
                    .push_group(vec![traj(i as u64, (i % 4) as u64)])
                    .unwrap();
            }
            store
        };
        // every strategy returns the same multiset, in its own order
        let mut sets: Vec<Vec<u64>> = Vec::new();
        for sampling in [
            SamplingStrategy::Fifo,
            SamplingStrategy::FreshestFirst,
            SamplingStrategy::StalenessWeighted,
        ] {
            let store = mk(sampling);
            let mut ids: Vec<u64> = store
                .sample(n, Duration::from_millis(5))
                .unwrap()
                .iter()
                .map(|t| t.group_id)
                .collect();
            assert_eq!(store.occupancy(), 0);
            ids.sort();
            sets.push(ids);
        }
        assert_eq!(sets[0], sets[1]);
        assert_eq!(sets[1], sets[2]);
        assert_eq!(sets[0], (0..n as u64).collect::<Vec<_>>());
    });
}
