//! Analysis-plane property tests: the [`LogHistogram`] core (shard-merge
//! == whole-stream over random partitions, the documented quantile
//! relative-error bound on random AND adversarial bucket-boundary
//! inputs, garbage tolerance), and the end-to-end `analyze` pipeline
//! over synthetic event streams (balance invariants, attribution
//! fractions, critical-path extraction, dropped-event accounting, and
//! the measured-vs-DES divergence round trip).

use llamarl::analysis::{analyze_file, attribute, extract, load, PLANES};
use llamarl::trace;
use llamarl::util::prop::run_prop;
use llamarl::util::stats::LogHistogram;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("llamarl_analysis_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

const QS: &[f64] = &[0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];

/// The exact nearest-rank order statistic the histogram's quantile is
/// specified against: the `ceil(q*n)`-th smallest value.
fn nearest_rank(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    let k = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[k - 1]
}

fn assert_error_bound(vals: &[f64], ctx: &str) {
    let mut h = LogHistogram::new();
    for &v in vals {
        h.record(v);
    }
    let mut sorted = vals.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for &q in QS {
        let exact = nearest_rank(&sorted, q);
        let est = h.quantile(q);
        let bound = exact * LogHistogram::RELATIVE_ERROR * (1.0 + 1e-9);
        assert!(
            (est - exact).abs() <= bound,
            "{ctx}: q={q} exact={exact:e} est={est:e} bound={bound:e} (n={})",
            vals.len()
        );
    }
}

// ---------------------------------------------------------------------------
// Histogram core

#[test]
fn prop_shard_merge_equals_whole_stream() {
    run_prop("shard_merge_equals_whole_stream", 200, |g| {
        let n = g.size(1, 400);
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            // mostly in-range positives, seasoned with garbage the low
            // bucket absorbs
            vals.push(match g.usize(0, 9) {
                0 => 0.0,
                1 => -g.f64(0.1, 10.0),
                2 => g.f64(1e250, 1e300),
                _ => g.f64(1e-8, 1e5),
            });
        }
        let shards = g.usize(1, 8);
        let mut parts = vec![LogHistogram::new(); shards];
        let mut whole = LogHistogram::new();
        for &v in &vals {
            whole.record(v);
            parts[g.usize(0, shards - 1)].record(v);
        }
        let mut merged = LogHistogram::new();
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(whole.count(), merged.count());
        assert_eq!(whole.min().to_bits(), merged.min().to_bits());
        assert_eq!(whole.max().to_bits(), merged.max().to_bits());
        // sums differ only by float association order
        let tol = 1e-9 * whole.sum().abs().max(1.0);
        assert!((whole.sum() - merged.sum()).abs() <= tol);
        // quantiles depend only on bucket counts + min/max, which the
        // bucket-wise add preserves exactly
        for &q in QS {
            assert_eq!(
                whole.quantile(q).to_bits(),
                merged.quantile(q).to_bits(),
                "q={q}"
            );
        }
    });
}

#[test]
fn prop_quantile_error_bound_random() {
    run_prop("quantile_error_bound_random", 200, |g| {
        let n = g.size(1, 500);
        let vals: Vec<f64> = (0..n).map(|_| g.f64(1e-6, 1e4)).collect();
        assert_error_bound(&vals, "random");
    });
}

#[test]
fn prop_quantile_error_bound_adversarial_boundaries() {
    run_prop("quantile_error_bound_boundaries", 200, |g| {
        let n = g.size(1, 300);
        let mut vals = Vec::with_capacity(n);
        let mut prev = 1.0;
        for _ in 0..n {
            let v = match g.usize(0, 4) {
                // exact bucket lower edges 2^e * (1 + s/16): the worst
                // case for a bucketing scheme with open/closed edge bugs
                0 | 1 => {
                    let e = g.i64(-30, 18) as f64;
                    let s = g.i64(0, 15) as f64;
                    e.exp2() * (1.0 + s / 16.0)
                }
                // exact powers of two (sub-bucket 0 edges)
                2 => (g.i64(-30, 18) as f64).exp2(),
                // a hair below an edge (previous bucket's last value)
                3 => {
                    let e = g.i64(-30, 18) as f64;
                    e.exp2() * (1.0 + g.i64(0, 15) as f64 / 16.0) * (1.0 - 1e-14)
                }
                // duplicates pile mass on a single bucket
                _ => prev,
            };
            prev = v;
            vals.push(v);
        }
        assert_error_bound(&vals, "boundaries");
    });
}

#[test]
fn histogram_garbage_does_not_panic() {
    let garbage = [
        0.0,
        -0.0,
        -1.0,
        -1e308,
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        1e-320, // subnormal
        f64::MIN_POSITIVE,
        f64::MAX,
        1e300,
        (-34f64).exp2(), // exact grid edges
        (21f64).exp2(),
        1.0,
    ];
    let mut h = LogHistogram::new();
    for &v in &garbage {
        h.record(v);
    }
    assert_eq!(h.count(), garbage.len() as u64);
    for &q in QS {
        // never NaN once something was recorded
        assert!(!h.quantile(q).is_nan(), "q={q}");
    }
    let empty = LogHistogram::new();
    assert!(empty.quantile(0.5).is_nan());
    assert_eq!(empty.quantile_or(0.5, 7.0), 7.0);
    // merging an empty histogram is the identity on every readout
    let mut merged = h.clone();
    merged.merge(&empty);
    assert_eq!(merged.count(), h.count());
    for &q in QS {
        assert_eq!(merged.quantile(q).to_bits(), h.quantile(q).to_bits());
    }
}

// ---------------------------------------------------------------------------
// Synthetic event streams

fn ev(out: &mut String, t_us: f64, track: &str, ph: &str, name: &str, value: f64) {
    out.push_str(&format!(
        "{{\"t_us\":{t_us},\"track\":\"{track}\",\"ph\":\"{ph}\",\
         \"name\":\"{name}\",\"value\":{value}}}\n"
    ));
}

#[test]
fn analyze_balanced_stream() {
    let mut s = String::new();
    // 3 steps: per step, a generator decodes 800us (100us of it blocked on
    // the channel), then the controller trains for 200us
    for k in 0..3 {
        let base = k as f64 * 1000.0;
        ev(&mut s, base, "generator-0", "B", trace::GEN_CHUNK, k as f64);
        ev(&mut s, base + 600.0, "generator-0", "B", trace::SEND_BLOCKED, 0.0);
        ev(&mut s, base + 700.0, "generator-0", "E", trace::SEND_BLOCKED, 0.0);
        ev(&mut s, base + 800.0, "generator-0", "E", trace::GEN_CHUNK, 0.0);
        ev(&mut s, base + 800.0, "controller", "B", trace::TRAIN, k as f64);
        ev(&mut s, base + 1000.0, "controller", "E", trace::TRAIN, 0.0);
    }
    let path = tmp("balanced.jsonl");
    std::fs::write(&path, &s).unwrap();

    let a = analyze_file(&path, false).unwrap();
    assert!(a.run.violations.is_empty(), "{:?}", a.run.violations);
    assert_eq!(a.run.events, 18);
    assert_eq!(a.run.spans.len(), 9);
    assert!((a.run.wall_secs() - 3000e-6).abs() < 1e-12);

    // attribution invariants and exact class charges
    assert_eq!(a.tracks.len(), 2);
    for t in &a.tracks {
        assert!(t.busy_frac() <= 1.0 + 1e-9, "{}: {}", t.track, t.busy_frac());
        let classes = t.compute_secs + t.channel_secs + t.sync_secs + t.offload_secs;
        assert!((classes - t.busy_secs).abs() < 1e-9);
        assert!((t.busy_secs + t.idle_secs - t.window_secs).abs() < 1e-9);
    }
    let generator = a.tracks.iter().find(|t| t.track == "generator-0").unwrap();
    assert!((generator.channel_secs - 300e-6).abs() < 1e-12); // 3 x 100us
    assert!((generator.compute_secs - 2100e-6).abs() < 1e-12); // 3 x 700us

    // critical path: one window per train span, each dominated by decode
    assert_eq!(a.path.steps.len(), 3);
    for st in &a.path.steps {
        assert_eq!(st.bounding, "generate");
    }
    assert_eq!(a.path.bounding, "generate");
    assert_eq!(a.path.totals.len(), PLANES.len());

    // merged histogram view sees all shards of each name
    let merged = a.hists.merged_by_name();
    assert_eq!(merged[trace::TRAIN].count(), 3);
    assert_eq!(merged[trace::GEN_CHUNK].count(), 3);
    // 200us trains: the p50 estimate carries the documented bound
    let p50 = merged[trace::TRAIN].quantile(0.5);
    assert!((p50 - 200e-6).abs() <= 200e-6 * LogHistogram::RELATIVE_ERROR * 1.001);
}

#[test]
fn analyze_detects_imbalance_and_unclosed() {
    let mut s = String::new();
    ev(&mut s, 0.0, "t0", "B", trace::GEN_CHUNK, 0.0);
    ev(&mut s, 10.0, "t0", "E", trace::TRAIN, 0.0); // closes the wrong span
    ev(&mut s, 20.0, "t1", "E", trace::SCORE, 0.0); // E without B
    ev(&mut s, 30.0, "t2", "B", trace::TRAIN_STEP, 1.0); // never closed
    let path = tmp("unbalanced.jsonl");
    std::fs::write(&path, &s).unwrap();

    let run = load(&path).unwrap();
    assert_eq!(run.violations.len(), 3, "{:?}", run.violations);
    assert_eq!(run.unclosed, 1);
    assert!(run.spans.is_empty());
}

#[test]
fn analyze_reads_dropped_counter_outside_window() {
    let mut s = String::new();
    ev(&mut s, 100.0, "t0", "B", trace::TRAIN, 0.0);
    ev(&mut s, 600.0, "t0", "E", trace::TRAIN, 0.0);
    // the collector's final tally lands long after the run; it must be
    // read but not stretch the wall-clock window
    ev(&mut s, 9e9, "collector", "C", trace::DROPPED_EVENTS, 7.0);
    let path = tmp("dropped.jsonl");
    std::fs::write(&path, &s).unwrap();

    let run = load(&path).unwrap();
    assert_eq!(run.dropped_events, 7);
    assert!((run.wall_secs() - 500e-6).abs() < 1e-12);
}

#[test]
fn divergence_round_trips_a_deterministic_sync_run() {
    // a synthetic journal whose timeline IS the sync DES's structure:
    // gen 1000us -> score 200us -> train 300us -> sync 100us, 3 steps,
    // no gaps. Calibrated back through simulate_sync, every shared
    // segment and the wall clock must come back at ratio ~1.
    let mut s = String::new();
    s.push_str("{\"kind\":\"meta\",\"config\":{\"mode\":\"sync\",\"seed\":3}}\n");
    for k in 0..3 {
        let base = k as f64 * 1600.0;
        ev(&mut s, base, "controller", "B", trace::GENERATE, k as f64);
        ev(&mut s, base + 1000.0, "controller", "E", trace::GENERATE, 0.0);
        ev(&mut s, base + 1000.0, "controller", "B", trace::SCORE, k as f64);
        ev(&mut s, base + 1200.0, "controller", "E", trace::SCORE, 0.0);
        ev(&mut s, base + 1200.0, "controller", "B", trace::TRAIN, k as f64);
        ev(&mut s, base + 1500.0, "controller", "E", trace::TRAIN, 0.0);
        ev(&mut s, base + 1500.0, "controller", "B", trace::WEIGHT_SYNC, k as f64);
        ev(&mut s, base + 1600.0, "controller", "E", trace::WEIGHT_SYNC, 0.0);
    }
    let path = tmp("divergence.jsonl");
    std::fs::write(&path, &s).unwrap();

    let a = analyze_file(&path, true).unwrap();
    let d = a.divergence.expect("--des requested");
    assert_eq!(d.mode, "sync");
    assert_eq!(d.steps, 3);
    assert!((d.wall_ratio - 1.0).abs() < 1e-6, "wall_ratio={}", d.wall_ratio);
    for name in ["generate", "score", "train", "weight_sync"] {
        let seg = d.segments.iter().find(|s| s.name == name).unwrap();
        let r = seg.ratio.unwrap_or_else(|| panic!("{name}: no prediction"));
        assert!((r - 1.0).abs() < 1e-6, "{name}: ratio={r}");
    }
    // segments the run never exercised predict 0 and report no ratio
    let publish = d.segments.iter().find(|s| s.name == "publish_block").unwrap();
    assert!(publish.ratio.is_none());
}

#[test]
fn divergence_without_config_is_a_clear_error() {
    let mut s = String::new();
    ev(&mut s, 0.0, "t0", "B", trace::TRAIN, 0.0);
    ev(&mut s, 10.0, "t0", "E", trace::TRAIN, 0.0);
    let path = tmp("no_meta.jsonl");
    std::fs::write(&path, &s).unwrap();
    let err = analyze_file(&path, true).unwrap_err();
    assert!(format!("{err}").contains("config"), "{err}");
}

#[test]
fn prop_random_balanced_streams_hold_invariants() {
    run_prop("random_balanced_streams", 60, |g| {
        let names = [
            trace::GEN_CHUNK,
            trace::TRAIN_STEP,
            trace::SEND_BLOCKED,
            trace::WEIGHT_SYNC,
            trace::OFFLOAD_WAIT,
            "custom_phase",
        ];
        let mut s = String::new();
        let tracks = g.usize(1, 3);
        for tr in 0..tracks {
            let track = format!("track-{tr}");
            let mut t = g.f64(0.0, 100.0);
            for _ in 0..g.usize(1, 6) {
                t += g.f64(0.0, 500.0);
                let dur = g.f64(100.0, 2000.0);
                let name = *g.choice(&names);
                ev(&mut s, t, &track, "B", name, 0.0);
                if dur > 400.0 {
                    // one properly nested child
                    let child = *g.choice(&names);
                    ev(&mut s, t + dur * 0.25, &track, "B", child, 0.0);
                    ev(&mut s, t + dur * 0.75, &track, "E", child, 0.0);
                }
                ev(&mut s, t + dur, &track, "E", name, 0.0);
                t += dur;
            }
        }
        let path = tmp(&format!("prop_stream_{}.jsonl", g.usize(0, 1 << 30)));
        std::fs::write(&path, &s).unwrap();

        let run = load(&path).unwrap();
        assert!(run.violations.is_empty(), "{:?}", run.violations);
        let attrs = attribute(&run.spans, run.t_min_us, run.t_max_us);
        assert_eq!(attrs.len(), tracks);
        for a in &attrs {
            assert!(a.busy_frac() <= 1.0 + 1e-9, "{}: {}", a.track, a.busy_frac());
            let classes = a.compute_secs + a.channel_secs + a.sync_secs + a.offload_secs;
            assert!((classes - a.busy_secs).abs() < 1e-9 * a.busy_secs.max(1e-12));
            assert!(a.idle_secs >= 0.0);
            assert!((a.busy_secs + a.idle_secs - a.window_secs).abs() < 1e-9);
        }
        let cp = extract(&run.spans, run.t_min_us, run.t_max_us);
        assert!(cp.bounding == "none" || PLANES.contains(&cp.bounding));
        for st in &cp.steps {
            assert!(st.bounding == "none" || PLANES.contains(&st.bounding));
        }
    });
}
