//! Runtime-level tests against the real `nano` artifacts: manifest
//! validation, executable round trips, generation semantics, train-step
//! behaviour. These need `make artifacts` to have run (they are skipped
//! with a message otherwise, so `cargo test` works on a fresh checkout).

use llamarl::model::{load_init_params, Tokenizer, EOS_ID, PAD_ID};
use llamarl::runtime::{Dtype, HostTensor, Manifest, Runtime};

fn artifacts() -> Option<&'static str> {
    const DIR: &str = "artifacts/nano";
    if std::path::Path::new(DIR).join("manifest.json").exists() {
        Some(DIR)
    } else {
        eprintln!("skipping: {DIR} missing (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_is_consistent() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(dir).unwrap();
    assert_eq!(m.config.name, "nano");
    // layout covers exactly num_params
    let last = m.param_layout.last().unwrap();
    let last_size: usize = last.shape.iter().product();
    assert_eq!(last.offset + last_size, m.num_params);
    // offsets strictly increasing & contiguous
    let mut off = 0;
    for e in &m.param_layout {
        assert_eq!(e.offset, off);
        off += e.shape.iter().product::<usize>();
    }
    // all five artifacts present with single outputs
    for name in [
        "generate_chunk",
        "train_step",
        "extract_params",
        "extract_metrics",
        "logprobs_eval",
    ] {
        let a = m.artifact(name).unwrap();
        assert!(!a.inputs.is_empty());
        assert!(m.artifact_path(name).unwrap().exists());
    }
    assert_eq!(m.artifact("train_step").unwrap().output.dtype, Dtype::F32);
}

#[test]
fn init_params_load_and_are_finite() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(dir).unwrap();
    let p = load_init_params(&m).unwrap();
    assert_eq!(p.len(), m.num_params);
    assert!(p.iter().all(|x| x.is_finite()));
    // layer-norm scales initialized to 1
    let ln = m
        .param_layout
        .iter()
        .find(|e| e.name == "layer0.ln1_scale")
        .unwrap();
    assert!(p[ln.offset..ln.offset + 4].iter().all(|x| *x == 1.0));
}

#[test]
fn generate_chunk_executes_and_respects_semantics() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(dir).unwrap();
    let c = rt.config().clone();
    let (b, s, ch) = (c.gen_batch, c.max_seq, c.gen_chunk);
    let tok = Tokenizer::new(c.vocab).unwrap();
    let prompt = tok.encode_prompt("12+34=").unwrap();

    let mut tokens = vec![PAD_ID; b * s];
    let mut lens = vec![1i32; b];
    let mut frozen = vec![0i32; b];
    for i in 0..b {
        tokens[i * s..i * s + prompt.len()].copy_from_slice(&prompt);
        lens[i] = prompt.len() as i32;
    }
    frozen[b - 1] = 1;
    let params = load_init_params(&rt.manifest).unwrap();

    let out = rt
        .execute(
            "generate_chunk",
            &[
                HostTensor::F32(params, vec![rt.manifest.num_params]),
                HostTensor::I32(tokens, vec![b, s]),
                HostTensor::I32(lens.clone(), vec![b]),
                HostTensor::I32(frozen, vec![b]),
                HostTensor::I32(vec![123], vec![1]),
                HostTensor::F32(vec![1.0], vec![1]),
                HostTensor::I32(vec![0], vec![1]),
            ],
        )
        .unwrap();
    let out = out.to_vec::<f32>().unwrap();
    let row_w = 2 * ch + 2;
    assert_eq!(out.len(), b * row_w);
    for i in 0..b - 1 {
        let row = &out[i * row_w..(i + 1) * row_w];
        let new_len = row[2 * ch] as usize;
        assert!(new_len > lens[i] as usize && new_len <= s);
        for j in 0..(new_len - lens[i] as usize) {
            let t = row[j] as i32;
            assert!((0..c.vocab as i32).contains(&t));
            assert!(row[ch + j] <= 0.0, "logp must be <= 0");
        }
    }
    // frozen row untouched
    let fr = &out[(b - 1) * row_w..b * row_w];
    assert_eq!(fr[2 * ch] as i32, lens[b - 1]);
    assert_eq!(fr[2 * ch + 1], 1.0);
    assert!(fr[..ch].iter().all(|t| *t as i32 == PAD_ID));
    let _ = EOS_ID;
}

#[test]
fn train_step_moves_params_and_counts_steps() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(dir).unwrap();
    let c = rt.config().clone();
    let (b, t) = (c.train_batch, c.train_seq);
    let params = load_init_params(&rt.manifest).unwrap();
    let total = rt.manifest.train_state.total;
    let mut state = params.clone();
    state.resize(total, 0.0);

    let tokens: Vec<i32> = (0..b * t).map(|i| (i % 40 + 3) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|i| ((i + 1) % 40 + 3) as i32).collect();
    let blogp = vec![-2.0f32; b * t];
    let adv = vec![1.0f32; b * t];
    let mask = vec![1.0f32; b * t];
    let lens = vec![t as i32; b];
    let hyp = vec![1e-3f32, 4.0, 1.0];

    let state_b = rt.upload(&HostTensor::F32(state, vec![total])).unwrap();
    let new_state = rt
        .execute_buffers(
            "train_step",
            &[
                &state_b,
                &rt.upload(&HostTensor::I32(tokens, vec![b, t])).unwrap(),
                &rt.upload(&HostTensor::I32(targets, vec![b, t])).unwrap(),
                &rt.upload(&HostTensor::F32(blogp, vec![b, t])).unwrap(),
                &rt.upload(&HostTensor::F32(adv, vec![b, t])).unwrap(),
                &rt.upload(&HostTensor::F32(mask, vec![b, t])).unwrap(),
                &rt.upload(&HostTensor::I32(lens, vec![b])).unwrap(),
                &rt.upload(&HostTensor::F32(hyp, vec![3])).unwrap(),
            ],
        )
        .unwrap();

    // metrics: step == 1, token_count == b*t, grad_norm > 0
    let met_b = rt.execute_buffers("extract_metrics", &[&new_state]).unwrap();
    let met = rt.fetch_f32(&met_b).unwrap();
    assert_eq!(met[0], 1.0, "step counter");
    let idx = |n: &str| rt.manifest.metric_index(n).unwrap();
    assert_eq!(met[1 + idx("token_count")], (b * t) as f32);
    assert!(met[1 + idx("grad_norm")] > 0.0);
    assert!(met[1 + idx("entropy")] > 0.0);

    // params moved
    let p_b = rt.execute_buffers("extract_params", &[&new_state]).unwrap();
    let new_params = rt.fetch_f32(&p_b).unwrap();
    assert_eq!(new_params.len(), rt.manifest.num_params);
    let diff: f32 = params
        .iter()
        .zip(&new_params)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 0.0, "params must move");
}

#[test]
fn logprobs_eval_matches_semantics() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(dir).unwrap();
    let c = rt.config().clone();
    let (b, t) = (c.train_batch, c.train_seq);
    let params = load_init_params(&rt.manifest).unwrap();
    let tokens: Vec<i32> = (0..b * t).map(|i| (i % 30 + 3) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|i| ((i * 7) % 30 + 3) as i32).collect();
    let lens = vec![t as i32; b];
    let out = rt
        .execute(
            "logprobs_eval",
            &[
                HostTensor::F32(params, vec![rt.manifest.num_params]),
                HostTensor::I32(tokens, vec![b, t]),
                HostTensor::I32(targets, vec![b, t]),
                HostTensor::I32(lens, vec![b]),
            ],
        )
        .unwrap();
    let lp = out.to_vec::<f32>().unwrap();
    assert_eq!(lp.len(), b * t);
    assert!(lp.iter().all(|x| *x <= 0.0 && x.is_finite()));
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(dir).unwrap();
    let res = rt.execute(
        "extract_params",
        &[HostTensor::F32(vec![0.0; 3], vec![3])],
    );
    assert!(res.is_err(), "wrong shape must be rejected before PJRT");
    let res = rt.execute("extract_params", &[]);
    assert!(res.is_err(), "wrong arity must be rejected");
    assert!(rt.execute("no_such_artifact", &[]).is_err());
}
