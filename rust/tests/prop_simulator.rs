//! Property tests on the cluster simulator — most importantly a numeric
//! verification of **Theorem 7.5**: for ANY problem instance satisfying
//! Assumption 7.1 (monotone-decreasing per-sample time), the optimized
//! asynchronous step time is <= the optimized synchronous step time; and
//! strictly smaller whenever the solver's grid admits a non-degenerate
//! split.

use llamarl::simulator::problem::{
    default_grid, eval_async_config, solve_async, solve_sync, ProblemSpec,
};
use llamarl::simulator::{simulate_timeline, DesConfig};
use llamarl::util::prop::{run_prop, Gen};

/// Random instance satisfying Assumption 7.1: eta(b) = c0/b + c1 with
/// c0, c1 > 0 is monotone decreasing.
fn random_problem(g: &mut Gen) -> ProblemSpec {
    let w0 = g.f64(1e9, 1e12);
    ProblemSpec {
        g0: g.f64(64.0, 4096.0).round(),
        b0: g.f64(128.0, 4096.0).round(),
        m0: g.f64(16e9, 140e9),
        w0,
        wg: w0 * g.f64(0.5, 1.0),
        a_t: g.f64(1e7, 5e9),
        k_g: g.f64(1e7, 5e9),
        eta_t: {
            let c0 = g.f64(0.01, 10.0);
            let c1 = g.f64(0.01, 5.0);
            Box::new(move |b| c0 / b + c1)
        },
        eta_g: {
            let c0 = g.f64(0.01, 10.0);
            let c1 = g.f64(0.01, 5.0);
            Box::new(move |b| c0 / b + c1)
        },
        bt_grid: default_grid(),
        bg_grid: default_grid(),
        pen_t: Box::new(|_| 1.0),
        pen_g: Box::new(|_| 1.0),
        sync_straggler: 1.0,
        // pure paper form (Definition 7.3: tau is m-independent)
        tp_alpha: 0.0,
        m_ref: 1.0,
        trainer_fsdp: false,
    }
}

#[test]
fn theorem_7_5_async_never_slower_than_sync() {
    run_prop("theorem75", 150, |g| {
        let p = random_problem(g);
        // ensure feasibility: one instance must fit in the cluster
        if p.min_mt(1.0) + p.min_mg(1.0) > p.g0 {
            return; // infeasible instance, skip
        }
        let sync = solve_sync(&p);
        let asn = solve_async(&p);
        assert!(
            asn.step_secs <= sync.step_secs * (1.0 + 1e-9),
            "Theorem 7.5 violated: async {} > sync {} (bt={} bg={} m={})",
            asn.step_secs,
            sync.step_secs,
            sync.bt,
            sync.bg,
            sync.m
        );
    });
}

#[test]
fn theorem_7_5_strict_on_continuous_relaxation() {
    // With the same batch point available to both and memory loose enough
    // that ceil() effects vanish, the inequality chain (11) is strict.
    run_prop("theorem75_strict", 100, |g| {
        let mut p = random_problem(g);
        p.m0 = g.f64(100e9, 200e9);
        p.g0 = 1e6; // effectively unconstrained GPU count
        if p.min_mt(1.0) + p.min_mg(1.0) > p.g0 {
            return;
        }
        let sync = solve_sync(&p);
        let asn = solve_async(&p);
        assert!(
            asn.step_secs < sync.step_secs,
            "expected strict improvement: async {} vs sync {}",
            asn.step_secs,
            sync.step_secs
        );
    });
}

#[test]
fn async_optimum_beats_arbitrary_async_configs() {
    // the solver's optimum is a true lower bound over the searched grid
    run_prop("async_opt", 60, |g| {
        let p = random_problem(g);
        if p.min_mt(1.0) + p.min_mg(1.0) > p.g0 {
            return;
        }
        let opt = solve_async(&p);
        for _ in 0..10 {
            let bt = *g.choice(&p.bt_grid);
            let bg = *g.choice(&p.bg_grid);
            let mt = p.min_mt(bt);
            let mg = p.min_mg(bg);
            if mt + mg > p.g0 {
                continue;
            }
            let theta = g.f64(0.05, 0.95);
            let t = eval_async_config(&p, bt, bg, mt, mg, theta);
            assert!(
                opt.step_secs <= t * (1.0 + 1e-9),
                "solver missed a better config: {} < {}",
                t,
                opt.step_secs
            );
        }
    });
}

#[test]
fn memory_constraints_hold_at_optimum() {
    run_prop("mem_constraints", 100, |g| {
        let p = random_problem(g);
        if p.min_mt(1.0) + p.min_mg(1.0) > p.g0 {
            return;
        }
        let a = solve_async(&p);
        assert!((4.0 * p.w0 + p.a_t * a.bt) / a.mt <= p.m0 * 1.0001);
        assert!((p.wg + p.k_g * a.bg) / a.mg <= p.m0 * 1.0001);
        assert!(a.mt + a.mg <= p.g0 * 1.0001);
        assert!(a.theta > 0.0 && a.theta < 1.0);
        // Lemma B.3: theta equalizes the two sides
        let tt = a.eta_t * a.mt / a.theta;
        let tg = a.eta_g * a.mg / (1.0 - a.theta);
        assert!((tt - tg).abs() <= 1e-6 * tt.max(tg));
    });
}

#[test]
fn des_async_at_least_as_fast_and_lag_bounded() {
    run_prop("des_async", 40, |g| {
        let cfg = DesConfig {
            steps: g.usize(10, 60),
            batch: g.usize(8, 64),
            concurrency: g.usize(2, 32),
            gen_mean_secs: g.f64(1.0, 20.0),
            gen_sigma: g.f64(0.1, 1.2),
            train_secs: g.f64(0.5, 20.0),
            score_secs: g.f64(0.0, 1.0),
            queue_capacity: g.usize(1, 4),
            partial_rollout_cap: f64::INFINITY,
            weight_sync_secs: 0.0,
            sync_overlap: false,
            publish_block_secs: 0.0,
            background_publish: false,
            seed: g.i64(0, 1 << 30) as u64,
        };
        let (s, a) = simulate_timeline(&cfg);
        assert!(
            a.total_secs <= s.total_secs * 1.001,
            "DES async slower: {} vs {}",
            a.total_secs,
            s.total_secs
        );
        assert!(a.mean_lag_steps <= cfg.queue_capacity as f64 + 1e-9);
        // utilization accounting sane
        for r in [&s, &a] {
            assert!(r.gen_idle_frac >= -1e-9 && r.gen_idle_frac <= 1.0);
            assert!(r.train_idle_frac >= -1e-9 && r.train_idle_frac <= 1.0);
        }
    });
}

#[test]
fn ddma_model_scales_linearly_ps_model_does_not() {
    use llamarl::ddma::ps_baseline::PsModel;
    use llamarl::ddma::topology::DdmaModel;
    run_prop("ddma_scaling", 50, |g| {
        let ddma = DdmaModel::calibrated();
        let ps = PsModel::calibrated();
        let params = g.f64(1e9, 500e9);
        let gpus = g.usize(8, 2048);
        // constant shard size => constant DDMA time (linear scalability)
        let t1 = ddma.sync_secs(params, gpus);
        let t2 = ddma.sync_secs(params * 2.0, gpus * 2);
        assert!((t1 - t2).abs() / t1 < 1e-6);
        // PS cost is superlinear in model size regardless of GPUs
        let p1 = ps.sync_secs(params);
        let p2 = ps.sync_secs(params * 2.0);
        assert!(p2 > 2.0 * p1 * 0.999, "ps must be superlinear");
        // and DDMA beats PS at every scale the paper reports
        assert!(ddma.sync_secs(params, gpus.max(64)) < ps.sync_secs(params));
    });
}
