//! Tracing-plane tests: recorder correctness under threaded contention,
//! span nesting discipline, the disabled-path overhead bound, the Chrome
//! export ↔ `util::json` round trip, and the telemetry snapshot sampler.
//!
//! The recorder is process-global (one ring registry, one enable flag), so
//! every test serializes on `TEST_LOCK`; `Collector::start` additionally
//! clears stale ring contents, so each session begins clean.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use llamarl::trace::{self, chrome, Collector, EventKind, Sampler};
use llamarl::util::json::Value;
use llamarl::util::prop::{run_prop, Gen};

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join("llamarl_trace_plane").join(name)
}

#[test]
fn threaded_recorder_loses_nothing_under_contention() {
    let _g = lock();
    let path = tmp("stress_events.jsonl");
    let c = Collector::start(&path).unwrap();

    const THREADS: usize = 8;
    const PER: usize = 1500; // < RING_CAP even with zero intermediate drains
    let mut handles = Vec::new();
    for t in 0..THREADS {
        handles.push(
            std::thread::Builder::new()
                .name(format!("stress-{t}"))
                .spawn(move || {
                    for i in 0..PER {
                        trace::instant(trace::STORE_ADMIT, i as f64);
                        if i % 256 == 0 {
                            std::thread::yield_now();
                        }
                    }
                })
                .unwrap(),
        );
    }
    for h in handles {
        h.join().unwrap();
    }
    let log = c.finish().unwrap();
    assert_eq!(log.dropped, 0, "no ring overflow");

    for t in 0..THREADS {
        let track = format!("stress-{t}");
        let evs: Vec<_> = log.events.iter().filter(|e| e.track == track).collect();
        assert_eq!(evs.len(), PER, "track {track} lost or duplicated events");
        for (i, e) in evs.iter().enumerate() {
            // SPSC order preserved: no torn or reordered slots
            assert_eq!(e.value, i as f64, "track {track} event {i}");
            assert_eq!(e.name, trace::STORE_ADMIT);
        }
        for w in evs.windows(2) {
            assert!(
                w[0].t_nanos <= w[1].t_nanos,
                "track {track} timestamps must be monotone"
            );
        }
    }

    // the streaming JSONL log carries every event with the line schema
    let text = std::fs::read_to_string(&path).unwrap();
    let mut lines = 0usize;
    for line in text.lines() {
        let v = Value::parse(line).unwrap();
        v.req_f64("t_us").unwrap();
        v.req_str("track").unwrap();
        assert_eq!(v.req_str("ph").unwrap(), "i");
        v.req_str("name").unwrap();
        v.req_f64("value").unwrap();
        lines += 1;
    }
    assert_eq!(lines, THREADS * PER);
}

#[test]
fn span_nesting_preserves_stack_discipline() {
    let _g = lock();
    let path = tmp("nesting_events.jsonl");
    let c = Collector::start(&path).unwrap();

    const NAMES: [&str; 4] = [
        trace::GENERATE,
        trace::SCORE,
        trace::TRAIN,
        trace::WEIGHT_SYNC,
    ];

    fn nest(g: &mut Gen, depth: usize, exp: &Mutex<Vec<(&'static str, bool)>>) {
        let name = *g.choice(&NAMES);
        exp.lock().unwrap().push((name, true));
        let s = trace::span_with(name, depth as f64);
        if depth < 4 {
            let kids = g.usize(0, 2);
            for _ in 0..kids {
                nest(g, depth + 1, exp);
            }
        }
        drop(s);
        exp.lock().unwrap().push((name, false));
    }

    // a dedicated named thread makes the track unambiguous
    let expected = std::thread::Builder::new()
        .name("prop-spans".into())
        .spawn(|| {
            let expected = Mutex::new(Vec::new());
            run_prop("span_nesting", 30, |g| nest(g, 0, &expected));
            expected.into_inner().unwrap()
        })
        .unwrap()
        .join()
        .unwrap();

    let log = c.finish().unwrap();
    assert_eq!(log.dropped, 0);
    let got: Vec<(&str, bool)> = log
        .events
        .iter()
        .filter(|e| e.track == "prop-spans")
        .map(|e| (e.name, matches!(e.kind, EventKind::Begin)))
        .collect();
    assert_eq!(got, expected, "recorded B/E stream must match the program");

    // replay: every End closes the innermost open Begin of the same name
    let mut stack: Vec<&str> = Vec::new();
    for (name, is_begin) in &got {
        if *is_begin {
            stack.push(name);
        } else {
            assert_eq!(stack.pop(), Some(*name), "unbalanced span nesting");
        }
    }
    assert!(stack.is_empty(), "every span must close");
}

#[test]
fn disabled_path_adds_no_measurable_overhead() {
    let _g = lock();
    trace::disable();
    let t0 = Instant::now();
    const N: u64 = 1_000_000;
    for i in 0..N {
        let _s = trace::span_with(trace::GENERATE, i as f64);
        trace::instant(trace::VERSION_MINT, i as f64);
        std::hint::black_box(i);
    }
    let per_call = t0.elapsed().as_secs_f64() / (2 * N) as f64;
    // one relaxed atomic load per call; 1 µs is ~2 orders of magnitude of
    // headroom even for an unoptimized build on a loaded CI machine
    assert!(
        per_call < 1e-6,
        "disabled trace call cost {per_call:.2e}s per call"
    );
}

#[test]
fn chrome_export_round_trips_through_util_json() {
    let _g = lock();
    let events_path = tmp("chrome_events.jsonl");
    let chrome_path = tmp("chrome_trace.json");
    let c = Collector::start(&events_path).unwrap();

    std::thread::Builder::new()
        .name("chrome-track".into())
        .spawn(|| {
            let s = trace::span_with(trace::SYNC_OVERLAP, 7.0);
            trace::instant(trace::VERSION_MINT, 7.0);
            trace::counter("store_occupancy", 3.0);
            drop(s);
        })
        .unwrap()
        .join()
        .unwrap();

    let log = c.finish().unwrap();
    chrome::export(&log, &chrome_path).unwrap();

    let v = Value::parse(&std::fs::read_to_string(&chrome_path).unwrap()).unwrap();
    let events = v.req_array("traceEvents").unwrap();
    assert!(!events.is_empty());
    assert_eq!(v.req_str("displayTimeUnit").unwrap(), "ms");
    assert_eq!(
        v.req("otherData").unwrap().req_f64("dropped_events").unwrap(),
        0.0
    );

    let ph_of = |e: &Value| e.get("ph").and_then(|p| p.as_str()).map(str::to_string);
    // one thread_name metadata event names our track
    assert!(events.iter().any(|e| {
        ph_of(e).as_deref() == Some("M")
            && e.get("args")
                .and_then(|a| a.get("name"))
                .and_then(|n| n.as_str())
                == Some("chrome-track")
    }));
    // every phase letter appears, and real events carry pid/tid/ts
    for want in ["B", "E", "i", "C"] {
        let ev = events
            .iter()
            .find(|e| ph_of(e).as_deref() == Some(want))
            .unwrap_or_else(|| panic!("no {want} event in export"));
        ev.req_f64("pid").unwrap();
        ev.req_f64("tid").unwrap();
        ev.req_f64("ts").unwrap();
    }
    // span names stay in the DES timeline vocabulary
    assert!(events
        .iter()
        .any(|e| e.get("name").and_then(|n| n.as_str()) == Some(trace::SYNC_OVERLAP)));
}

#[test]
fn snapshot_sampler_writes_periodic_series() {
    let _g = lock();
    let path = tmp("snapshots.jsonl");
    let s = Sampler::start(&path, 0.02, || {
        Value::object(vec![("trainer_step", Value::num(42.0))])
    })
    .unwrap();
    std::thread::sleep(Duration::from_millis(70));
    s.stop();

    let text = std::fs::read_to_string(&path).unwrap();
    let mut last_elapsed = -1.0f64;
    let mut lines = 0usize;
    for line in text.lines() {
        let v = Value::parse(line).unwrap();
        assert_eq!(v.req_f64("trainer_step").unwrap(), 42.0);
        let e = v.req_f64("elapsed_secs").unwrap();
        assert!(e >= last_elapsed, "elapsed_secs must be non-decreasing");
        last_elapsed = e;
        lines += 1;
    }
    assert!(lines >= 2, "expected a series, got {lines} snapshot(s)");
}
