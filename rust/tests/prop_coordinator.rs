//! Property tests on coordinator invariants: channel routing, batching,
//! advantage baselines, weight-bus consistency, tokenizer round trips.
//! (Hand-rolled harness in util::prop — proptest is not in the offline
//! vendor set.)

use std::sync::atomic::Ordering;
use std::sync::Arc;

use llamarl::coordinator::channel::{gather_channel, scatter_channel, Message};
use llamarl::data::{Difficulty, Problem};
use llamarl::ddma::WeightsBus;
use llamarl::model::Tokenizer;
use llamarl::rl::{group_advantages, pack_batch, Baseline, FinishReason, Trajectory};
use llamarl::util::prop::{run_prop, Gen};

fn mk_traj(g: &mut Gen, group_id: u64, n_replicas: usize) -> Trajectory {
    let plen = g.usize(1, 6);
    let rlen = g.usize(1, 8);
    Trajectory {
        group_id,
        replica: 0,
        n_replicas,
        problem: Problem {
            prompt: "1+1=".into(),
            answer: "2".into(),
            difficulty: Difficulty::Add1,
        },
        prompt_tokens: (0..plen).map(|i| (i % 50 + 3) as i32).collect(),
        response_tokens: (0..rlen).map(|i| (i % 50 + 3) as i32).collect(),
        behavior_logp: (0..rlen).map(|_| g.f64(-5.0, 0.0) as f32).collect(),
        gen_version: g.i64(0, 20) as u64,
        chunks: 1,
        finish: FinishReason::Eos,
        reward: if g.bool() { 1.0 } else { 0.0 },
        advantage: 0.0,
    }
}

#[test]
fn scatter_round_robin_preserves_every_message() {
    run_prop("scatter_preserves", 50, |g| {
        let n_consumers = g.usize(1, 5);
        let n_msgs = g.usize(1, 40);
        let (tx, rxs) = scatter_channel("t", n_msgs + 1, n_consumers);
        for i in 0..n_msgs {
            let mut t = mk_traj(g, i as u64, 1);
            t.group_id = i as u64;
            tx.send(Message::Scored(vec![t])).unwrap();
        }
        drop(tx);
        let mut seen: Vec<u64> = vec![];
        for rx in &rxs {
            while let Some(Message::Scored(v)) = rx.try_recv() {
                seen.extend(v.iter().map(|t| t.group_id));
            }
        }
        seen.sort();
        let want: Vec<u64> = (0..n_msgs as u64).collect();
        assert_eq!(seen, want, "every message delivered exactly once");
    });
}

#[test]
fn gather_from_n_producers_delivers_all_items() {
    run_prop("gather_all", 30, |g| {
        let n_producers = g.usize(1, 6);
        let per = g.usize(1, 10);
        let (tx, rx) = gather_channel("t", n_producers * per + 1);
        let mut handles = vec![];
        for p in 0..n_producers {
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let t = Trajectory {
                        group_id: (p * 1000 + i) as u64,
                        replica: 0,
                        n_replicas: 1,
                        problem: Problem {
                            prompt: "p".into(),
                            answer: "a".into(),
                            difficulty: Difficulty::Add1,
                        },
                        prompt_tokens: vec![1],
                        response_tokens: vec![2],
                        behavior_logp: vec![0.0],
                        gen_version: 0,
                        chunks: 1,
                        finish: FinishReason::Eos,
                        reward: 0.0,
                        advantage: 0.0,
                    };
                    tx.send(Message::Trajectories(vec![t])).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        drop(tx);
        let mut count = 0;
        while let Some(Message::Trajectories(v)) = rx.try_recv() {
            count += v.len();
        }
        assert_eq!(count, n_producers * per);
        assert_eq!(rx.stats.items.load(Ordering::Relaxed) as usize, count);
    });
}

#[test]
fn group_mean_advantages_sum_to_zero() {
    run_prop("adv_zero_sum", 100, |g| {
        let n = g.usize(2, 8);
        let mut group: Vec<Trajectory> = (0..n).map(|_| mk_traj(g, 7, n)).collect();
        group_advantages(&mut group, Baseline::GroupMean);
        let sum: f32 = group.iter().map(|t| t.advantage).sum();
        assert!(sum.abs() < 1e-4, "sum={sum}");
        // uniform-reward groups give exactly zero advantage everywhere
        let r = group[0].reward;
        if group.iter().all(|t| t.reward == r) {
            assert!(group.iter().all(|t| t.advantage == 0.0));
        }
    });
}

#[test]
fn rloo_advantage_matches_direct_formula() {
    run_prop("rloo_direct", 100, |g| {
        let n = g.usize(2, 6);
        let mut group: Vec<Trajectory> = (0..n).map(|_| mk_traj(g, 3, n)).collect();
        let rewards: Vec<f32> = group.iter().map(|t| t.reward).collect();
        group_advantages(&mut group, Baseline::LeaveOneOut);
        for (i, t) in group.iter().enumerate() {
            let others: f32 = rewards
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, r)| r)
                .sum();
            let want = t.reward - others / (n as f32 - 1.0);
            assert!((t.advantage - want).abs() < 1e-5);
        }
    });
}

#[test]
fn pack_batch_roundtrips_every_token_and_mask_is_aligned() {
    run_prop("pack_roundtrip", 100, |g| {
        let b = g.usize(1, 6);
        let t_dim = 24;
        let n_rows = g.usize(1, b);
        let trajs: Vec<Trajectory> = (0..n_rows)
            .map(|_| {
                let mut t = mk_traj(g, 0, 1);
                t.advantage = g.f64(-1.0, 1.0) as f32;
                t
            })
            .collect();
        let batch = pack_batch(&trajs, b, t_dim).unwrap();
        for (row, tr) in trajs.iter().enumerate() {
            let base = row * t_dim;
            let plen = tr.prompt_tokens.len();
            let rlen = tr.response_tokens.len();
            // inputs reconstruct prompt ++ response[..-1]
            let mut full = tr.prompt_tokens.clone();
            full.extend(&tr.response_tokens);
            for i in 0..(plen + rlen - 1) {
                assert_eq!(batch.tokens[base + i], full[i]);
                assert_eq!(batch.targets[base + i], full[i + 1]);
            }
            // mask exactly covers response targets
            let mask_count: f32 = batch.mask[base..base + t_dim].iter().sum();
            assert_eq!(mask_count as usize, rlen);
            for (j, &lp) in tr.behavior_logp.iter().enumerate() {
                let pos = base + plen - 1 + j;
                assert_eq!(batch.blogp[pos], lp);
                assert_eq!(batch.mask[pos], 1.0);
                assert_eq!(batch.adv[pos], tr.advantage);
            }
            assert_eq!(batch.lens[row] as usize, plen + rlen - 1);
        }
        // padding rows fully masked
        for row in n_rows..b {
            let base = row * t_dim;
            assert!(batch.mask[base..base + t_dim].iter().all(|m| *m == 0.0));
        }
    });
}

#[test]
fn weights_bus_snapshots_are_consistent_under_concurrency() {
    // Readers racing a publisher must only ever see fully-published
    // versions: data[i] == version for every element.
    run_prop("bus_consistency", 5, |g| {
        let len = g.usize(100, 5000);
        let bus = Arc::new(WeightsBus::new(vec![0.0; len]));
        let writer = {
            let bus = bus.clone();
            std::thread::spawn(move || {
                for v in 1..=20u64 {
                    bus.publish(vec![v as f32; len]);
                }
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let bus = bus.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        let snap = bus.latest();
                        let v = snap.data[0];
                        assert!(snap.data.iter().all(|x| *x == v), "torn snapshot");
                        assert_eq!(v as u64, snap.version);
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(bus.version(), 20);
    });
}

#[test]
fn tokenizer_roundtrip_arbitrary_task_strings() {
    run_prop("tok_roundtrip", 200, |g| {
        let tok = Tokenizer::new(64).unwrap();
        let charset = "0123456789+-*/=(). abcdefghijklmnopqrstuvwxyz";
        let n = g.usize(0, 30);
        let s: String = (0..n)
            .map(|_| {
                let i = g.usize(0, charset.len() - 1);
                charset.as_bytes()[i] as char
            })
            .collect();
        let ids = tok.encode(&s).unwrap();
        assert_eq!(tok.decode(&ids), s);
    });
}

#[test]
fn quantization_roundtrip_bounded_by_per_tensor_scale() {
    use llamarl::model::simulate_int8_roundtrip;
    use llamarl::runtime::ParamEntry;
    run_prop("quant_bounded", 60, |g| {
        let n_tensors = g.usize(1, 5);
        let mut layout = Vec::new();
        let mut data = Vec::new();
        let mut off = 0;
        for i in 0..n_tensors {
            let len = g.usize(1, 64);
            layout.push(ParamEntry {
                name: format!("t{i}"),
                shape: vec![len],
                offset: off,
            });
            for _ in 0..len {
                data.push(g.f64(-2.0, 2.0) as f32);
            }
            off += len;
        }
        let rt = simulate_int8_roundtrip(&data, &layout);
        for entry in &layout {
            let len: usize = entry.shape.iter().product();
            let chunk = &data[entry.offset..entry.offset + len];
            let maxabs = chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let bound = maxabs / 127.0 / 2.0 + 1e-6;
            for (a, b) in chunk.iter().zip(&rt[entry.offset..entry.offset + len]) {
                assert!(
                    (a - b).abs() <= bound,
                    "err {} > bound {bound}",
                    (a - b).abs()
                );
            }
        }
    });
}
