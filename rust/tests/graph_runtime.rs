//! The single-controller execution graph: topology unit tests (no
//! artifacts needed), group-routing/EOF fan-in behaviour, a mid-run
//! generator-failure stress test (clean join, no hang), a mode-parity
//! suite asserting each mode's report invariants match the pre-refactor
//! drivers on the nano artifacts at fixed seed, and the elastic-fleet
//! chaos suite: seeded kill schedules must restart replicas in place
//! (partials migrated, no global stop) and converge to the unperturbed
//! run's trained-row counts.

use llamarl::coordinator::channel::{routed_channel, Message};
use llamarl::coordinator::graph::{topology_with_rows, EdgeKind, Graph, LeasePolicy, NodeKind};
use llamarl::coordinator::{run_training, Mode, PipelineConfig};
use llamarl::data::{Difficulty, Problem};
use llamarl::rl::{FinishReason, Trajectory};

fn cfg_for(mode: Mode) -> PipelineConfig {
    PipelineConfig {
        mode,
        ..PipelineConfig::default()
    }
}

#[test]
fn sync_topology_is_the_same_graph_stepped() {
    let mut cfg = cfg_for(Mode::Sync);
    cfg.n_reward_workers = 2;
    cfg.eval_every = 2;
    let g = topology_with_rows(&cfg, 8);
    g.check().unwrap();
    assert!(g.stepped, "sync is the stepped scheduler, not a separate engine");
    assert_eq!(g.mode_name, "sync");
    assert_eq!(g.replicas(NodeKind::Generator), 1);
    assert_eq!(g.replicas(NodeKind::Reward), 2);
    assert_eq!(g.replicas(NodeKind::Trainer), 1);
    assert_eq!(g.replicas(NodeKind::Evaluator), 1);
    // sync generator leases per step; no weight-sync slot (re-attaches to
    // the DDMA master directly)
    let gen = g.node(NodeKind::Generator).unwrap();
    assert!(matches!(gen.lease, LeasePolicy::PerStep(_)));
    assert!(!gen.sync_slot);
    // channels must absorb a whole step: capacity (2*rows).max(64)
    let Some(e) = g.edge_into(NodeKind::Reward) else {
        panic!("generations edge missing")
    };
    assert_eq!(e.kind, EdgeKind::GroupRouted { capacity: 64 });
    let Some(e) = g.edge_into(NodeKind::Trainer) else {
        panic!("scored edge missing")
    };
    assert_eq!(e.kind, EdgeKind::Gather { capacity: 64 });
}

#[test]
fn async_topology_replicas_and_edges() {
    let mut cfg = cfg_for(Mode::Async);
    cfg.n_generator_workers = 3;
    cfg.n_reward_workers = 2;
    cfg.queue_capacity = 5;
    cfg.scored_capacity = 7;
    let g = topology_with_rows(&cfg, 8);
    g.check().unwrap();
    assert!(!g.stepped);
    assert_eq!(g.mode_name, "async");
    assert_eq!(g.replicas(NodeKind::Generator), 3);
    assert_eq!(g.replicas(NodeKind::Reward), 2);
    assert_eq!(g.replicas(NodeKind::Evaluator), 0, "eval_every=0 -> absent");
    let gen = g.node(NodeKind::Generator).unwrap();
    assert!(matches!(gen.lease, LeasePolicy::Lifetime(_)));
    assert!(gen.sync_slot, "async generators receive streamed versions");
    assert_eq!(
        g.edge_into(NodeKind::Reward).unwrap().kind,
        EdgeKind::GroupRouted { capacity: 5 }
    );
    assert_eq!(
        g.edge_into(NodeKind::Trainer).unwrap().kind,
        EdgeKind::Gather { capacity: 7 }
    );
}

#[test]
fn buffered_topology_routes_scored_through_the_store() {
    let mut cfg = cfg_for(Mode::AsyncBuffered);
    cfg.n_generator_workers = 2;
    let g = topology_with_rows(&cfg, 8);
    g.check().unwrap();
    assert_eq!(g.mode_name, "async_buffered");
    assert_eq!(g.edge_into(NodeKind::Trainer).unwrap().kind, EdgeKind::Store);
    assert!(matches!(
        g.edge_into(NodeKind::Reward).unwrap().kind,
        EdgeKind::GroupRouted { .. }
    ));
}

#[test]
fn check_rejects_malformed_topologies() {
    let base = topology_with_rows(&cfg_for(Mode::Async), 8);
    base.check().unwrap();

    // no trainer
    let mut g: Graph = base.clone();
    g.nodes.retain(|n| n.kind != NodeKind::Trainer);
    assert!(g.check().is_err());

    // zero reward replicas
    let mut g = base.clone();
    for n in g.nodes.iter_mut() {
        if n.kind == NodeKind::Reward {
            n.replicas = 0;
        }
    }
    assert!(g.check().is_err());

    // non-routed generations edge would split advantage groups
    let mut g = base.clone();
    for e in g.edges.iter_mut() {
        if e.to == NodeKind::Reward {
            e.kind = EdgeKind::Gather { capacity: 4 };
        }
    }
    assert!(g.check().is_err());

    // the stepped scheduler drives exactly one generator
    let mut g = base.clone();
    g.stepped = true;
    for n in g.nodes.iter_mut() {
        if n.kind == NodeKind::Generator {
            n.replicas = 2;
        }
    }
    assert!(g.check().is_err());

    // stepped graphs cannot honor sync slots, lifetime leases, or a store
    // scored edge — check() must reject them rather than silently running
    // with different semantics (the async topology declares all three)
    let mut g = base.clone();
    g.stepped = true;
    for n in g.nodes.iter_mut() {
        if n.kind == NodeKind::Generator {
            n.replicas = 1;
        }
    }
    assert!(g.check().is_err(), "stepped + sync_slot/lifetime lease must fail");

    let mut g = topology_with_rows(&cfg_for(Mode::Sync), 8);
    g.edges.retain(|e| e.to != NodeKind::Trainer);
    g.edges.push(llamarl::coordinator::graph::EdgeSpec {
        name: "scored",
        from: NodeKind::Reward,
        to: NodeKind::Trainer,
        kind: EdgeKind::Store,
    });
    assert!(g.check().is_err(), "stepped + store scored edge must fail");
}

#[test]
fn trainer_fleet_topology_check_and_dot() {
    // a data-parallel trainer fleet rides the buffered topology: replicas
    // come from n_trainer_workers, check() demands the store edge, and the
    // DOT labels track the whole fleet
    let mut cfg = cfg_for(Mode::AsyncBuffered);
    cfg.n_trainer_workers = 3;
    let g = topology_with_rows(&cfg, 8);
    g.check().unwrap();
    assert_eq!(g.replicas(NodeKind::Trainer), 3);
    let dot = g.to_dot();
    assert!(dot.contains("trainer x3"));
    assert!(dot.contains("tracks: trainer-0..trainer-2"));

    // the stepped scheduler drives exactly one trainer
    let mut cfg = cfg_for(Mode::Sync);
    cfg.n_trainer_workers = 2;
    assert!(topology_with_rows(&cfg, 8).check().is_err());

    // a fleet without the store edge cannot shard its sampling: the async
    // (channel-scored) topology must be rejected at check() time
    let mut cfg = cfg_for(Mode::Async);
    cfg.n_trainer_workers = 2;
    assert!(topology_with_rows(&cfg, 8).check().is_err());

    // zero trainers is malformed whatever the mode
    let mut g = topology_with_rows(&cfg_for(Mode::AsyncBuffered), 8);
    for n in g.nodes.iter_mut() {
        if n.kind == NodeKind::Trainer {
            n.replicas = 0;
        }
    }
    assert!(g.check().is_err());
}

#[test]
fn periodic_topology_is_buffered_with_its_own_name() {
    let mut cfg = cfg_for(Mode::Periodic);
    cfg.n_generator_workers = 2;
    cfg.n_trainer_workers = 2;
    let g = topology_with_rows(&cfg, 8);
    g.check().unwrap();
    assert_eq!(g.mode_name, "periodic");
    assert!(!g.stepped, "periodic generators free-run between fences");
    assert_eq!(g.edge_into(NodeKind::Trainer).unwrap().kind, EdgeKind::Store);
    assert_eq!(g.replicas(NodeKind::Trainer), 2);
}

#[test]
fn dot_rendering_names_every_fleet_and_edge() {
    let mut cfg = cfg_for(Mode::AsyncBuffered);
    cfg.n_generator_workers = 2;
    cfg.n_reward_workers = 3;
    let dot = topology_with_rows(&cfg, 8).to_dot();
    assert!(dot.starts_with("digraph llamarl {"));
    assert!(dot.contains("generator x2"));
    assert!(dot.contains("reward x3"));
    assert!(dot.contains("trainer x1"));
    // node labels carry the telemetry/trace track names so a dumped
    // graph maps 1:1 onto trace-export tracks
    assert!(dot.contains("tracks: generator-0..generator-1"));
    assert!(dot.contains("tracks: reward-0..reward-2"));
    assert!(dot.contains("track: trainer"));
    assert!(dot.contains("rollout store"));
    assert!(dot.contains("group-routed"));
    assert!(dot.contains("DDMA weights bus"));
    assert!(dot.ends_with("}\n"));
}

fn traj(group_id: u64, replica: usize, n_replicas: usize) -> Trajectory {
    Trajectory {
        group_id,
        replica,
        n_replicas,
        problem: Problem {
            prompt: "1+1=".into(),
            answer: "2".into(),
            difficulty: Difficulty::Add1,
        },
        prompt_tokens: vec![1],
        response_tokens: vec![2],
        behavior_logp: vec![-0.5],
        gen_version: 0,
        chunks: 1,
        finish: FinishReason::Eos,
        reward: 0.0,
        advantage: 0.0,
    }
}

#[test]
fn group_routing_preserves_group_integrity_across_producers() {
    // Many producer threads emit interleaved replicas of many groups; the
    // routed channel must land EVERY replica of group g on consumer g % n,
    // and deliver every trajectory exactly once.
    let n_consumers = 3;
    let n_producers = 4;
    let n_groups = 24u64;
    let n_replicas = 4;
    let (tx, rxs) = routed_channel("integrity", 256, n_consumers);
    let mut handles = Vec::new();
    for p in 0..n_producers {
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            // producer p emits replica p of every group, one mixed batch
            // per few groups (exercises the per-message split)
            for chunk in (0..n_groups).collect::<Vec<_>>().chunks(5) {
                let batch: Vec<Trajectory> =
                    chunk.iter().map(|g| traj(*g, p, n_replicas)).collect();
                tx.send(Message::Trajectories(batch)).unwrap();
            }
            tx.send_eof();
        }));
    }
    drop(tx);
    for h in handles {
        h.join().unwrap();
    }
    let mut total = 0usize;
    for (i, rx) in rxs.iter().enumerate() {
        let mut eofs = 0;
        while eofs < n_producers {
            match rx.recv().unwrap() {
                Message::Trajectories(v) => {
                    for t in &v {
                        assert_eq!(
                            t.group_id % n_consumers as u64,
                            i as u64,
                            "replica of group {} routed to the wrong consumer",
                            t.group_id
                        );
                    }
                    total += v.len();
                }
                Message::Eof => eofs += 1,
                Message::Scored(_) => panic!("unexpected scored message"),
            }
        }
        // EOF fan-in: every producer's EOF reached this consumer — n_eofs
        // is exactly the producer count, the contract the reward fleet's
        // drain counting relies on
        assert_eq!(eofs, n_producers);
    }
    assert_eq!(total, n_groups as usize * n_producers);
}

// ---------------------------------------------------------------------------
// Artifact-gated suites (skip gracefully without `make artifacts`, exactly
// like tests/integration.rs).
// ---------------------------------------------------------------------------

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/nano/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/nano missing (run `make artifacts`)");
    }
    ok
}

fn base_cfg(tag: &str) -> PipelineConfig {
    PipelineConfig {
        artifact_dir: "artifacts/nano".into(),
        max_steps: 3,
        max_response: 10,
        n_generations: 4,
        seed: 17,
        out_dir: std::env::temp_dir().join(format!("llamarl_graph_{tag}")),
        ..PipelineConfig::default()
    }
}

/// Mode parity: the graph runtime must report exactly what the
/// pre-refactor drivers reported for each mode — steps, zero-lag sync
/// semantics, channel-vs-store wait accounting — at a fixed seed.
#[test]
fn mode_parity_sync_matches_prerefactor_invariants() {
    if !have_artifacts() {
        return;
    }
    let cfg = PipelineConfig {
        mode: Mode::Sync,
        ..base_cfg("parity_sync")
    };
    let r1 = run_training(&cfg).unwrap();
    assert_eq!(r1.mode, "sync");
    assert_eq!(r1.steps, 3);
    assert_eq!(r1.records.len(), 3);
    assert!(r1.trajectories >= 3 * 4);
    assert!(r1.reward_groups > 0, "reward tally must flow through the hub");
    assert_eq!(r1.reward_rows_scored, r1.trajectories);
    for rec in &r1.records {
        assert_eq!(rec.max_lag, 0, "sync mode must stay on-policy");
        assert!((rec.mean_ratio - 1.0).abs() < 1e-2);
    }
    // no store in sync mode: the sampling-wait field stays zero
    assert_eq!(r1.trainer_sample_wait_secs, 0.0);
    assert!(r1.dataplane.is_none());

    // the stepped scheduler is single-threaded and seeded: a second run at
    // the same seed reproduces the training trajectory exactly
    let cfg2 = PipelineConfig {
        out_dir: std::env::temp_dir().join("llamarl_graph_parity_sync2"),
        ..cfg
    };
    let r2 = run_training(&cfg2).unwrap();
    assert_eq!(r1.records.len(), r2.records.len());
    for (a, b) in r1.records.iter().zip(&r2.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {} loss differs", a.step);
        assert_eq!(a.reward_mean.to_bits(), b.reward_mean.to_bits());
        assert_eq!(a.rows, b.rows);
    }
    assert_eq!(r1.tokens_generated, r2.tokens_generated);
    assert_eq!(r1.trajectories, r2.trajectories);
}

#[test]
fn mode_parity_async_and_buffered_report_distinct_wait_fields() {
    if !have_artifacts() {
        return;
    }
    let asy = run_training(&PipelineConfig {
        mode: Mode::Async,
        n_generator_workers: 2,
        max_steps: 4,
        ..base_cfg("parity_async")
    })
    .unwrap();
    assert_eq!(asy.mode, "async");
    assert_eq!(asy.steps, 4);
    assert!(asy.ddma_publishes >= 4);
    assert!(asy.dataplane.is_none());
    // async trainer waits on the scored CHANNEL, never the store
    assert_eq!(asy.trainer_sample_wait_secs, 0.0);
    for rec in &asy.records {
        assert!(rec.mean_ratio.is_finite() && rec.mean_ratio > 0.0);
    }

    let mut cfg = PipelineConfig {
        mode: Mode::AsyncBuffered,
        n_generator_workers: 2,
        max_steps: 4,
        ..base_cfg("parity_buf")
    };
    cfg.store.capacity = 64;
    cfg.store.max_staleness = Some(3);
    let buf = run_training(&cfg).unwrap();
    assert_eq!(buf.mode, "async_buffered");
    assert_eq!(buf.steps, 4);
    let dp = buf.dataplane.expect("buffered mode must report store telemetry");
    assert!(dp.admitted > 0);
    assert!(dp.max_sampled_lag <= 3);
    // buffered trainer waits inside store sampling, never the channel —
    // the fixed semantic split the old drivers conflated
    assert_eq!(buf.trainer_recv_blocked_secs, 0.0);
    assert_eq!(buf.trainer_sample_wait_secs, dp.sample_wait_secs);
}

#[test]
fn reward_fleet_scales_scoring_with_group_integrity() {
    if !have_artifacts() {
        return;
    }
    // A full group (n_generations replicas) must assemble on exactly one
    // reward node for the advantage baseline to be computable at all: if
    // routing ever split a group, no node would reach n_replicas rows and
    // the run could not complete its steps.
    for mode in [Mode::Async, Mode::AsyncBuffered] {
        let mut cfg = PipelineConfig {
            mode,
            n_generator_workers: 2,
            n_reward_workers: 3,
            max_steps: 3,
            ..base_cfg("fleet")
        };
        cfg.store.capacity = 64;
        let r = run_training(&cfg).unwrap();
        assert_eq!(r.steps, 3, "{mode:?} with a reward fleet must complete");
        assert!(r.reward_groups > 0);
        assert!(
            r.records.iter().all(|rec| rec.rows > 0),
            "every step trained on assembled groups"
        );
    }
    // sync mode drives the same fleet through the stepped scheduler
    let cfg = PipelineConfig {
        mode: Mode::Sync,
        n_reward_workers: 2,
        max_steps: 2,
        ..base_cfg("fleet_sync")
    };
    let r = run_training(&cfg).unwrap();
    assert_eq!(r.steps, 2);
    assert!(r.reward_groups > 0);
}

#[test]
fn midrun_generator_error_propagates_to_a_clean_join() {
    if !have_artifacts() {
        return;
    }
    // The injected failure hits after 2 decode chunks, mid-pipeline. The
    // graph runtime must record it, fan the stop out (closing the store in
    // buffered mode so nothing blocks), join every thread, and surface
    // the error — not hang, not panic, not return a bogus report. With the
    // default restart budget (0 -> RestartPolicy::Never) the supervisor
    // layer is pass-through and this pre-elastic contract is unchanged.
    for mode in [Mode::Async, Mode::AsyncBuffered] {
        let cfg = PipelineConfig {
            mode,
            n_generator_workers: 2,
            n_reward_workers: 2,
            max_steps: 50, // far more steps than the failure allows
            debug_fail_generator_after: Some(2),
            ..base_cfg("failprop")
        };
        let err = run_training(&cfg).expect_err("injected failure must surface");
        let msg = err.to_string();
        assert!(
            msg.contains("injected failure"),
            "{mode:?}: unexpected error: {msg}"
        );
    }
}

// ---------------------------------------------------------------------------
// Elastic fleet: chaos kills, supervised restarts, partial migration,
// convergence parity against the unperturbed run.
// ---------------------------------------------------------------------------

fn chaos_cfg(tag: &str) -> PipelineConfig {
    let mut cfg = PipelineConfig {
        mode: Mode::AsyncBuffered,
        n_generator_workers: 2,
        n_reward_workers: 2,
        max_steps: 4,
        ..base_cfg(tag)
    };
    cfg.store.capacity = 64;
    cfg
}

/// A seeded chaos schedule kills every generator once mid-rollout; the
/// supervisor must restart each in place — partials parked and resumed by
/// a survivor or the replacement, restarts journaled and counted — and the
/// run must complete every step with NO global stop.
#[test]
fn chaos_kills_restart_in_place_and_migrate_partials() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = chaos_cfg("chaos_restart");
    cfg.restart_max = 5;
    cfg.restart_backoff_ms = 1;
    cfg.chaos_kills = 2; // one kill per worker on attempt 0
    cfg.chaos_seed = 7;
    let r = run_training(&cfg).expect("chaos within the restart budget must not stop the run");
    assert_eq!(r.steps, cfg.max_steps, "every step must complete under churn");
    assert!(
        r.node_restarts >= 1,
        "the kill schedule must have forced at least one supervised restart"
    );
    // a generator killed mid-rollout had live slots past their prompt;
    // those park into the store and the resumed counter picks them up
    let dp = r.dataplane.expect("buffered mode reports store telemetry");
    assert!(
        dp.parked >= r.partials_migrated,
        "migrated partials ({}) must have been parked ({})",
        r.partials_migrated,
        dp.parked
    );

    // the journal carries one node_restart record per restart, with the
    // chaos error message — the durable evidence the CI chaos arm greps
    let journal = cfg.out_dir.join("journal.jsonl");
    let text = std::fs::read_to_string(&journal).unwrap();
    let restart_lines = text
        .lines()
        .filter(|l| l.contains("\"kind\":\"node_restart\""))
        .count() as u64;
    assert_eq!(
        restart_lines, r.node_restarts,
        "journal and telemetry must agree on restart count"
    );
}

/// Convergence parity: a chaos-perturbed run must land on the SAME
/// trainer-side counts as the unperturbed run — same steps, same total
/// trained rows. Restarts may shuffle which replica generated what, but
/// the training loop's demand (max_steps x train_batch) is invariant.
#[test]
fn chaos_run_converges_to_unperturbed_counts() {
    if !have_artifacts() {
        return;
    }
    let base = run_training(&chaos_cfg("chaos_base")).unwrap();

    let mut cfg = chaos_cfg("chaos_perturbed");
    cfg.restart_max = 4;
    cfg.restart_backoff_ms = 1;
    cfg.chaos_kills = 3;
    cfg.chaos_seed = 23;
    let chaos = run_training(&cfg).expect("perturbed run must converge, not stop");

    assert_eq!(chaos.steps, base.steps, "same optimizer steps");
    assert_eq!(chaos.records.len(), base.records.len());
    let rows = |r: &llamarl::coordinator::RunReport| -> usize {
        r.records.iter().map(|x| x.rows).sum()
    };
    assert_eq!(
        rows(&chaos),
        rows(&base),
        "chaos must not change how many rows the trainer consumed"
    );
    assert!(chaos.node_restarts >= 1, "the schedule must actually have killed");
}

/// An exhausted restart budget must fall back to the pre-elastic global
/// stop: error recorded, every thread joined, failure surfaced.
#[test]
fn exhausted_restart_budget_escalates_to_global_stop() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = chaos_cfg("chaos_exhaust");
    cfg.restart_max = 2;
    cfg.restart_backoff_ms = 1;
    cfg.chaos_kills = 50; // kills every attempt of every worker
    cfg.chaos_seed = 11;
    cfg.max_steps = 50;
    let err = run_training(&cfg).expect_err("a budget-exhausted replica must escalate");
    assert!(
        err.to_string().contains("injected failure"),
        "unexpected error: {err}"
    );
}

// ---------------------------------------------------------------------------
// Multi-trainer data parallelism + periodic asynchrony.
// ---------------------------------------------------------------------------

/// Period-fence degeneracy: with `period_steps = 1` every step is a
/// boundary, so the periodic trainer consumes exactly what sync consumes —
/// same step count, same trained-row totals — at a fixed seed.
#[test]
fn periodic_with_period_one_matches_sync_row_totals() {
    if !have_artifacts() {
        return;
    }
    let sync = run_training(&PipelineConfig {
        mode: Mode::Sync,
        ..base_cfg("periodic_sync_base")
    })
    .unwrap();
    let mut cfg = PipelineConfig {
        mode: Mode::Periodic,
        period_steps: 1,
        n_generator_workers: 2,
        ..base_cfg("periodic_p1")
    };
    cfg.store.capacity = 64;
    let per = run_training(&cfg).unwrap();
    assert_eq!(per.mode, "periodic");
    assert_eq!(per.steps, sync.steps);
    assert_eq!(per.records.len(), sync.records.len());
    let rows = |r: &llamarl::coordinator::RunReport| -> usize {
        r.records.iter().map(|x| x.rows).sum()
    };
    assert_eq!(
        rows(&per),
        rows(&sync),
        "period_steps=1 must reproduce sync's trained-row totals"
    );
}

/// A 2-replica trainer fleet must complete every step exactly once, with
/// the static round-robin partition visible in the merged records: step s
/// belongs to replica (s % n + n - 1) % n.
#[test]
fn trainer_fleet_covers_every_step_disjointly() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = PipelineConfig {
        mode: Mode::AsyncBuffered,
        n_generator_workers: 2,
        n_trainer_workers: 2,
        max_steps: 4,
        ..base_cfg("trainer_fleet")
    };
    cfg.store.capacity = 64;
    let r = run_training(&cfg).unwrap();
    assert_eq!(r.steps, 4, "the fleet clock is the max over replicas");
    assert_eq!(r.records.len(), 4, "every step trained exactly once");
    let mut seen = std::collections::HashSet::new();
    for rec in &r.records {
        assert!(seen.insert(rec.step), "step {} trained twice", rec.step);
        assert_eq!(
            rec.replica,
            ((rec.step as usize % 2) + 1) % 2,
            "step {} ran on the wrong replica",
            rec.step
        );
        assert!(rec.rows > 0);
    }
    assert!(
        r.records.iter().any(|rec| rec.replica == 1),
        "the peer replica must have trained its share"
    );
    // every replica publishes through its own registered bus publisher
    assert!(r.ddma_publishes >= 4);
}

/// A reward replica killed mid-run must restart in place: the supervisor
/// re-routes its inbound slot to a fresh receiver (producers retry across
/// the epoch bump), and the run completes every step.
#[test]
fn reward_panic_restarts_with_rerouted_channel() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = chaos_cfg("chaos_reward");
    cfg.restart_max = 5;
    cfg.restart_backoff_ms = 1;
    cfg.chaos_reward_kills = 2; // one panic per reward worker on attempt 0
    cfg.chaos_seed = 13;
    let r = run_training(&cfg).expect("a rerouted reward replica must not stop the run");
    assert_eq!(r.steps, cfg.max_steps, "every step must complete under reward churn");
    assert!(
        r.node_restarts >= 1,
        "the kill schedule must have forced at least one reward restart"
    );
    assert!(r.reward_groups > 0, "the replacement replica kept scoring");
}

/// The opt-in fleet controller must never destabilize a run: with resize
/// enabled the run completes identically (dynamic replicas suppress EOF,
/// retire cleanly, and their tallies fold into the report).
#[test]
fn elastic_resize_keeps_the_run_stable() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = chaos_cfg("elastic_resize");
    cfg.elastic_resize = true;
    cfg.resize_max_extra = 1;
    cfg.max_steps = 5;
    let r = run_training(&cfg).expect("resize hooks must not break the run");
    assert_eq!(r.steps, cfg.max_steps);
    assert!(r.trajectories > 0);
    // scale events are load-dependent (0 is legal on a fast machine), but
    // whatever the controller did must be internally consistent: every
    // scale-up it journaled is counted, and the report renders cleanly
    assert!(
        r.fleet_scale_ups >= r.fleet_scale_downs,
        "cannot retire more dynamic replicas than were spawned"
    );
}
