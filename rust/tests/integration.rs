//! End-to-end integration tests: full pipelines over the real nano
//! artifacts (sync baseline, async LlamaRL, pretraining, off-policy
//! semantics). Skipped gracefully if `make artifacts` has not run.

use llamarl::coordinator::{
    run_pretraining, run_training, Mode, PipelineConfig, PretrainConfig,
};
use llamarl::rl::Baseline;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/nano/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/nano missing (run `make artifacts`)");
    }
    ok
}

fn base_cfg(tag: &str) -> PipelineConfig {
    PipelineConfig {
        artifact_dir: "artifacts/nano".into(),
        max_steps: 3,
        max_response: 10,
        n_generations: 4,
        out_dir: std::env::temp_dir().join(format!("llamarl_it_{tag}")),
        ..PipelineConfig::default()
    }
}

#[test]
fn sync_pipeline_runs_and_is_on_policy() {
    if !have_artifacts() {
        return;
    }
    let cfg = PipelineConfig {
        mode: Mode::Sync,
        ..base_cfg("sync")
    };
    let r = run_training(&cfg).unwrap();
    assert_eq!(r.steps, 3);
    assert_eq!(r.records.len(), 3);
    assert!(r.trajectories >= 3 * 4_u64);
    // STRICT on-policy: every batch generated under the weights that train
    // on it -> importance ratio identically 1, KL ~ 0, zero lag.
    for rec in &r.records {
        assert_eq!(rec.max_lag, 0, "sync mode must have zero lag");
        assert!(
            (rec.mean_ratio - 1.0).abs() < 1e-2,
            "on-policy ratio 1.0, got {}",
            rec.mean_ratio
        );
        assert!(rec.approx_kl.abs() < 1e-2);
    }
    assert!(r.metrics_path.unwrap().exists());
}

#[test]
fn async_pipeline_runs_with_bounded_lag_and_backpressure_accounting() {
    if !have_artifacts() {
        return;
    }
    let cfg = PipelineConfig {
        mode: Mode::Async,
        n_generator_workers: 2,
        queue_capacity: 2,
        max_steps: 4,
        ..base_cfg("async")
    };
    let r = run_training(&cfg).unwrap();
    assert_eq!(r.steps, 4);
    assert!(r.ddma_publishes >= 4);
    // lag exists but is bounded by the pipeline depth
    let max_lag = r.records.iter().map(|x| x.max_lag).max().unwrap();
    assert!(max_lag <= 8, "lag {} out of bounds", max_lag);
    // importance ratios stay finite and positive
    for rec in &r.records {
        assert!(rec.mean_ratio.is_finite() && rec.mean_ratio > 0.0);
    }
}

#[test]
fn quantized_generator_produces_off_policy_ratios_in_sync_mode() {
    if !have_artifacts() {
        return;
    }
    // int8 generator + sync execution: lag is zero but mu != pi, so the
    // measured ratio must deviate from 1 — the quantization off-policy
    // source of paper §4.3.
    let cfg = PipelineConfig {
        mode: Mode::Sync,
        quantize_generator: true,
        max_steps: 2,
        ..base_cfg("quant")
    };
    let r = run_training(&cfg).unwrap();
    let any_deviation = r
        .records
        .iter()
        .any(|rec| (rec.mean_ratio - 1.0).abs() > 1e-4 || rec.approx_kl.abs() > 1e-5);
    assert!(
        any_deviation,
        "quantized behaviour policy must differ from learner: {:?}",
        r.records
    );
}

#[test]
fn buffered_pipeline_runs_with_enforced_staleness_bound() {
    if !have_artifacts() {
        return;
    }
    let bound = 3u64;
    let mut cfg = PipelineConfig {
        mode: Mode::AsyncBuffered,
        n_generator_workers: 2,
        max_steps: 4,
        ..base_cfg("buffered")
    };
    cfg.store.capacity = 64;
    cfg.store.max_staleness = Some(bound);
    let r = run_training(&cfg).unwrap();
    assert_eq!(r.steps, 4);
    assert_eq!(r.mode, "async_buffered");
    let dp = r.dataplane.expect("buffered mode must report store telemetry");
    assert!(dp.admitted > 0, "rows must flow through the store");
    assert!(dp.sampled > 0);
    assert!(
        dp.max_sampled_lag <= bound,
        "store handed out lag {} > bound {bound}",
        dp.max_sampled_lag
    );
    // the trainer's own per-batch lag accounting agrees with the bound
    // (+1 step in flight between sampling and the optimizer update)
    let max_lag = r.records.iter().map(|x| x.max_lag).max().unwrap();
    assert!(max_lag <= bound + 1, "realized lag {max_lag} out of bounds");
    for rec in &r.records {
        assert!(rec.mean_ratio.is_finite() && rec.mean_ratio > 0.0);
    }
}

#[test]
fn pretrain_then_rl_from_checkpoint() {
    if !have_artifacts() {
        return;
    }
    let out = std::env::temp_dir().join("llamarl_it_pre");
    let report = run_pretraining(
        &PretrainConfig {
            artifact_dir: "artifacts/nano".into(),
            steps: 30,
            lr: 2e-3,
            grad_clip: 1.0,
            seed: 3,
            log_every: 0,
        },
        &out,
    )
    .unwrap();
    assert_eq!(report.steps, 30);
    assert!(report.final_target_logp.is_finite());
    // RL resumes from the checkpoint
    let cfg = PipelineConfig {
        mode: Mode::Sync,
        init_checkpoint: Some(out),
        max_steps: 2,
        ..base_cfg("pre_rl")
    };
    let r = run_training(&cfg).unwrap();
    assert_eq!(r.steps, 2);
}

#[test]
fn rloo_baseline_works() {
    if !have_artifacts() {
        return;
    }
    let cfg = PipelineConfig {
        mode: Mode::Sync,
        baseline: Baseline::LeaveOneOut,
        max_steps: 2,
        ..base_cfg("rloo")
    };
    let r = run_training(&cfg).unwrap();
    assert_eq!(r.steps, 2);
}

#[test]
fn misconfiguration_is_rejected() {
    if !have_artifacts() {
        return;
    }
    // sync mode with train_batch (4) not divisible by n_generations
    let cfg = PipelineConfig {
        mode: Mode::Sync,
        n_generations: 3,
        ..base_cfg("bad")
    };
    assert!(run_training(&cfg).is_err());
    let cfg = PipelineConfig {
        max_steps: 0,
        ..base_cfg("bad2")
    };
    assert!(run_training(&cfg).is_err());
}
