//! Threaded stress tests for the background streaming weight-sync executor:
//! N concurrent publishers x M generator slots, across wire encodings.
//!
//! Invariants under arbitrary interleaving:
//!
//! * every slot converges to the bus's max version once the stream settles;
//! * no slot ever fronts a torn or mixed buffer — each swapped snapshot is
//!   self-consistent with the publish that produced it (checked via a
//!   content tag, so it holds regardless of which publisher won the race);
//! * the base-version fence never lets a delta land on a stale base: with
//!   the exact Delta encoding every swapped snapshot must be *bit-exact*
//!   self-consistent, which a single wrongly-based sparse packet would
//!   break;
//! * versions are minted in one total order across publishers.

use std::sync::Arc;

use llamarl::ddma::{BusOptions, WeightsBus};
use llamarl::weightsync::{Layout, ShardEncoding};

/// Publish payloads are self-describing: element i derives from the tag in
/// element 0. A buffer mixing two publishes (torn write, wrong-base delta)
/// cannot satisfy this for all i.
fn fill(tag: u32, n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| (tag.wrapping_mul(31).wrapping_add(i as u32) % 1013) as f32)
        .collect()
}

fn assert_consistent(data: &[f32], context: &str) {
    assert!(!data.is_empty());
    // recover the tag from element 0: tag*31 % 1013 == data[0]
    let d0 = data[0] as u32;
    let tag = (0..1013u32)
        .find(|t| t.wrapping_mul(31) % 1013 == d0)
        .unwrap_or_else(|| panic!("{context}: element 0 ({d0}) encodes no tag"));
    for (i, x) in data.iter().enumerate() {
        let want = (tag.wrapping_mul(31).wrapping_add(i as u32) % 1013) as f32;
        assert!(
            x.to_bits() == want.to_bits(),
            "{context}: element {i} = {x}, want {want} (tag {tag}) — torn or \
             wrongly-based buffer"
        );
    }
}

fn stress(encoding: ShardEncoding, n_publishers: usize, n_slots: usize) {
    let n = 1 << 10;
    let rounds = 60u64;
    let mut opts = BusOptions::new(Layout::fsdp(n, 4), Layout::tp_flat(n, 3));
    opts.encoding = encoding;
    opts.background = true;
    opts.link_groups = 3;
    let bus = Arc::new(WeightsBus::with_options(fill(0, n), opts).unwrap());

    let slots: Vec<_> = (0..n_slots).map(|_| bus.register_generator()).collect();

    // consumer threads: swap eagerly, checking consistency + monotonicity
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let consumers: Vec<_> = slots
        .iter()
        .enumerate()
        .map(|(si, slot)| {
            let slot = slot.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut last = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    if let Some(snap) = slot.swap_at_boundary() {
                        assert!(snap.version > last, "slot {si}: version regressed");
                        last = snap.version;
                        assert_consistent(&snap.data, &format!("slot {si} v{}", snap.version));
                    }
                    std::hint::black_box(slot.attach().version);
                }
            })
        })
        .collect();

    // publisher threads: distinct tags per publish, global version mint
    let publishers: Vec<_> = (0..n_publishers)
        .map(|p| {
            let bus = bus.clone();
            let pid = if p == 0 { 0 } else { bus.register_publisher() };
            std::thread::spawn(move || {
                let mut versions = Vec::new();
                for r in 0..rounds {
                    let tag = (p as u32 + 1) * 1000 + r as u32;
                    versions.push(bus.publish_from(pid, fill(tag, n)));
                }
                versions
            })
        })
        .collect();

    let mut all_versions: Vec<u64> = Vec::new();
    for h in publishers {
        let vs = h.join().unwrap();
        assert!(
            vs.windows(2).all(|w| w[0] < w[1]),
            "a publisher's own versions must be strictly increasing"
        );
        all_versions.extend(vs);
    }
    // one global mint across publishers: all versions distinct, none skipped
    all_versions.sort_unstable();
    let expected: Vec<u64> = (1..=n_publishers as u64 * rounds).collect();
    assert_eq!(all_versions, expected, "versions must form one total order");

    // settle the stream, stop consumers, then drain every slot
    bus.flush();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for c in consumers {
        c.join().unwrap();
    }
    let max_version = bus.version();
    assert_eq!(max_version, n_publishers as u64 * rounds);
    for (si, slot) in slots.iter().enumerate() {
        while slot.swap_at_boundary().is_some() {}
        let front = slot.attach();
        assert_eq!(
            front.version, max_version,
            "slot {si} must converge to the max version"
        );
        assert_consistent(&front.data, &format!("slot {si} final"));
        // final content must equal the winning publish exactly (bit-exact
        // even for delta: the master snapshot is always exact)
        let master = bus.latest();
        assert!(
            front
                .data
                .iter()
                .zip(master.data.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "slot {si}: converged content differs from the master snapshot"
        );
    }
    assert_eq!(bus.publisher_count(), n_publishers.max(1));
}

#[test]
fn stress_full_f32_three_publishers_four_slots() {
    stress(ShardEncoding::F32, 3, 4);
}

#[test]
fn stress_exact_delta_two_publishers_three_slots() {
    // Delta: a single wrongly-based sparse packet that slipped the fence
    // would corrupt a slot's buffer and fail assert_consistent bit-exactly.
    stress(ShardEncoding::Delta, 2, 3);
}

#[test]
fn stress_single_publisher_delta_matches_master() {
    stress(ShardEncoding::Delta, 1, 2);
}
