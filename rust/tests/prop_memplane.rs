//! Memory-plane invariants: pool accounting under randomized schedules,
//! planner feasibility, and the offload executor under racing targets.
//!
//! * the pool never leaks, never double-frees, never overcommits — checked
//!   against a shadow model across randomized acquire/release/relocate
//!   sequences and across randomized phase-lease schedules on a live
//!   plane;
//! * the planner's proof holds at runtime: whatever the phase schedule,
//!   device usage stays under the capacity the plan was admitted against;
//! * the background executor converges to the planned residency set under
//!   rapid latest-wins target flips and racing prefetch hints, without
//!   ever tearing a shard's contents.

use std::sync::atomic::Ordering;

use llamarl::memplane::plan::Phase;
use llamarl::memplane::pool::{AllocClass, AllocId, MemPool, MemSpec, Placement};
use llamarl::memplane::{MemPlane, MemPlaneConfig};
use llamarl::util::prop::{run_prop, Gen};
use llamarl::Error;

const MB: u64 = 1_000_000;

#[test]
fn prop_pool_accounting_matches_shadow_model() {
    run_prop("pool_accounting", 150, |g: &mut Gen| {
        let device_cap = g.usize(50, 400) as u64;
        let host_cap = g.usize(50, 400) as u64;
        let pool = MemPool::new(device_cap, host_cap);
        // shadow model: (id, bytes, placement)
        let mut live: Vec<(AllocId, u64, Placement)> = Vec::new();
        let mut dead: Vec<AllocId> = Vec::new();
        let used = |live: &Vec<(AllocId, u64, Placement)>, p: Placement| -> u64 {
            live.iter().filter(|(_, _, q)| *q == p).map(|(_, b, _)| b).sum()
        };
        for _ in 0..g.usize(10, 120) {
            match g.usize(0, 3) {
                0 => {
                    // acquire: must succeed exactly when it fits
                    let bytes = g.usize(1, 120) as u64;
                    let placement = if g.bool() { Placement::Device } else { Placement::Host };
                    let class = *g.choice(&AllocClass::ALL);
                    let (cap, u) = match placement {
                        Placement::Device => (device_cap, used(&live, Placement::Device)),
                        Placement::Host => (host_cap, used(&live, Placement::Host)),
                    };
                    match pool.acquire(class, bytes, placement) {
                        Ok(id) => {
                            assert!(u + bytes <= cap, "overcommit admitted");
                            live.push((id, bytes, placement));
                        }
                        Err(e) => {
                            assert!(u + bytes > cap, "fitting acquire refused: {e}");
                            assert!(matches!(e, Error::Capacity(_)));
                        }
                    }
                }
                1 => {
                    // release a live allocation, or require a double-free
                    // error for a dead one
                    if !live.is_empty() && g.bool() {
                        let i = g.usize(0, live.len() - 1);
                        let (id, _, _) = live.remove(i);
                        pool.release(id).expect("live release");
                        dead.push(id);
                    } else if let Some(id) = dead.last() {
                        assert!(
                            matches!(pool.release(*id), Err(Error::Capacity(_))),
                            "double free must error"
                        );
                    }
                }
                _ => {
                    // relocate: succeeds exactly when the target tier fits
                    if live.is_empty() {
                        continue;
                    }
                    let i = g.usize(0, live.len() - 1);
                    let (id, bytes, from) = live[i];
                    let to = match from {
                        Placement::Device => Placement::Host,
                        Placement::Host => Placement::Device,
                    };
                    let (cap, u) = match to {
                        Placement::Device => (device_cap, used(&live, Placement::Device)),
                        Placement::Host => (host_cap, used(&live, Placement::Host)),
                    };
                    match pool.relocate(id, to) {
                        Ok(()) => {
                            assert!(u + bytes <= cap);
                            live[i].2 = to;
                        }
                        Err(_) => assert!(u + bytes > cap, "fitting relocate refused"),
                    }
                }
            }
            let usage = pool.usage();
            assert_eq!(usage.device_used, used(&live, Placement::Device));
            assert_eq!(usage.host_used, used(&live, Placement::Host));
            assert_eq!(usage.live_allocs, live.len());
        }
        for (id, _, _) in live.drain(..) {
            pool.release(id).unwrap();
        }
        assert_eq!(pool.usage().live_allocs, 0);
        assert_eq!(pool.usage().device_used, 0);
        assert_eq!(pool.usage().host_used, 0);
    });
}

fn random_feasible_spec(g: &mut Gen) -> (MemSpec, u64) {
    let spec = MemSpec::new(
        g.usize(2, 16) as u64 * MB,
        g.usize(2, 16) as u64 * MB,
        g.usize(8, 32) as u64 * MB,
        g.usize(4, 32) as u64 * MB,
        g.usize(2, 16) as u64 * MB,
    );
    // between the worst phase (always feasible with offloads) and the
    // union: sometimes tight enough to force offloading, sometimes roomy
    let offload = [AllocClass::Grads, AllocClass::OptimState];
    let floor = llamarl::memplane::plan::auto_device_cap(&spec, true, false, &offload, 0.0);
    let cap = floor + (g.usize(0, 32) as u64) * MB;
    (spec, cap)
}

#[test]
fn prop_random_phase_schedules_never_leak_or_overcommit() {
    run_prop("memplane_phase_schedules", 25, |g: &mut Gen| {
        let (spec, cap) = random_feasible_spec(g);
        let background = g.bool();
        let plane = MemPlane::new(
            spec,
            &MemPlaneConfig {
                colocate: true,
                background,
                device_bytes: cap,
                host_bytes: spec.total() * 2,
                shards_per_class: g.usize(1, 6),
                offload_chunk_mb: 1,
                prefetch_depth: g.usize(0, 8),
                ..MemPlaneConfig::default()
            },
        )
        .expect("cap at/above the planner floor must be feasible");
        let phases = [Phase::Generate, Phase::Train, Phase::Sync];
        for _ in 0..g.usize(2, 12) {
            let p = *g.choice(&phases);
            let lease = plane.lease(p).expect("lease");
            if g.bool() {
                plane.hint_next(*g.choice(&phases));
            }
            for c in p.required() {
                lease.wait_class(*c).expect("required class resident");
            }
            assert!(plane.usage().device_used <= plane.device_cap());
            drop(lease);
        }
        plane.flush().expect("converge");
        plane.verify_integrity().expect("no torn shards");
        let usage = plane.usage();
        assert!(usage.device_used <= plane.device_cap());
        // every byte of every retained class is accounted exactly once
        // (leak or double-free would skew the total)
        let retained: u64 = AllocClass::ALL
            .iter()
            .filter(|c| !c.is_transient())
            .map(|c| spec.bytes(*c))
            .sum();
        assert!(usage.device_used + usage.host_used >= retained);
        assert!(usage.device_used + usage.host_used <= spec.total());
    });
}

#[test]
fn stress_racing_targets_converge_to_planned_residency() {
    let spec = MemSpec::new(8 * MB, 8 * MB, 16 * MB, 24 * MB, 8 * MB);
    let offload = [AllocClass::Grads, AllocClass::OptimState];
    let plane = MemPlane::new(
        spec,
        &MemPlaneConfig {
            colocate: true,
            background: true,
            device_bytes: 48 * MB,
            host_bytes: 128 * MB,
            offload_classes: offload.to_vec(),
            shards_per_class: 8,
            offload_chunk_mb: 1,
            prefetch_depth: 8,
            ..MemPlaneConfig::default()
        },
    )
    .unwrap();

    // flipper threads: rapid full lease cycles (generate evicts optimizer,
    // train pulls it back) with prefetch hints racing the evictions.
    // Threads synchronize per cycle via the lease-wait fences themselves.
    let rounds = 30;
    let flipper = {
        let plane = plane.clone();
        std::thread::spawn(move || {
            for i in 0..rounds {
                {
                    let g = plane.lease(Phase::Generate).expect("generate");
                    if i % 2 == 0 {
                        plane.hint_next(Phase::Train); // prefetch vs evict race
                    }
                    g.wait_shard(AllocClass::KvCache, 0).expect("kv head");
                }
                {
                    let t = plane.lease(Phase::Train).expect("train");
                    t.wait_shard(AllocClass::OptimState, 0).expect("optim head");
                    // drop without draining the rest: the next generate
                    // target supersedes the tail of this prefetch
                }
            }
        })
    };
    // integrity auditor racing the transfers
    let auditor = {
        let plane = plane.clone();
        std::thread::spawn(move || {
            for _ in 0..50 {
                plane.verify_integrity().expect("no torn shard mid-race");
                std::thread::yield_now();
            }
        })
    };
    flipper.join().unwrap();
    auditor.join().unwrap();

    // settle on Train: the executor must converge to exactly the planned
    // train-phase residency set
    let t = plane.lease(Phase::Train).unwrap();
    t.wait_class(AllocClass::OptimState).unwrap();
    t.wait_class(AllocClass::Grads).unwrap();
    plane.flush().unwrap();
    for (class, frac) in plane.device_fracs() {
        assert_eq!(frac, 1.0, "{} not fully resident after settle", class.name());
    }
    plane.verify_integrity().unwrap();
    let m = plane.metrics();
    assert!(
        m.superseded_targets.load(Ordering::Relaxed) > 0,
        "rapid flips must exercise latest-wins cancellation"
    );
    assert!(m.transferred_bytes() > 0);
    assert!(plane.usage().device_used <= plane.device_cap());
    drop(t);

    // the planner's capacity error is a hard gate, not a warning: the same
    // spec on a 30 MB rank must refuse to construct
    match MemPlane::new(
        spec,
        &MemPlaneConfig {
            colocate: true,
            device_bytes: 30 * MB,
            ..MemPlaneConfig::default()
        },
    ) {
        Err(err) => assert!(matches!(err, Error::Capacity(_)), "{err}"),
        Ok(_) => panic!("oversized colocation must not construct"),
    }
}

#[test]
fn concurrent_mode_is_accounting_only() {
    let spec = MemSpec::new(4 * MB, 4 * MB, 8 * MB, 8 * MB, 4 * MB);
    let plane = MemPlane::new(
        spec,
        &MemPlaneConfig {
            colocate: true,
            concurrent_phases: true,
            device_bytes: spec.total() + MB,
            ..MemPlaneConfig::default()
        },
    )
    .unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let plane = plane.clone();
            std::thread::spawn(move || {
                let phase = if i % 2 == 0 { Phase::Generate } else { Phase::Train };
                for _ in 0..20 {
                    let l = plane.lease(phase).expect("lease");
                    for c in phase.required() {
                        l.wait_class(*c).expect("resident");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    plane.flush().unwrap();
    assert_eq!(
        plane.metrics().transferred_bytes(),
        0,
        "concurrent phases must never move state"
    );
    plane.verify_integrity().unwrap();
}
