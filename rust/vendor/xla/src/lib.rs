//! Host-side stub of the `xla-rs` PJRT surface the `llamarl` crate uses.
//!
//! The original development image links a vendored `xla_extension` build;
//! this container does not ship it, and the offline crate universe cannot
//! fetch it. This stub keeps the exact API surface (`PjRtClient`,
//! `PjRtBuffer`, `PjRtLoadedExecutable`, `Literal`, `HloModuleProto`,
//! `XlaComputation`) so the crate compiles and every non-PJRT code path —
//! coordinator, channels, data plane, DDMA bus, simulator, tokenizer,
//! packing — runs for real. Host-side data plumbing (literals, buffers,
//! reshape, upload/fetch) is fully functional; only HLO *execution* is
//! unavailable: `PjRtClient::compile` returns an error, which surfaces
//! through `Runtime::prepare` exactly where a missing artifact bundle
//! would. Tests and examples already gate on `artifacts/*/manifest.json`
//! existing, so they skip (not fail) without the real backend.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml`; no call site mentions the stub.

use std::fmt;
use std::path::Path;

/// Error type matching the shape of `xla::Error` in the real bindings.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types that can cross the host/literal boundary.
pub trait ArrayElement: Copy {
    fn wrap(data: Vec<Self>) -> LitData;
    fn unwrap(lit: &LitData) -> Option<Vec<Self>>;
    const DTYPE: &'static str;
}

impl ArrayElement for f32 {
    fn wrap(data: Vec<Self>) -> LitData {
        LitData::F32(data)
    }
    fn unwrap(lit: &LitData) -> Option<Vec<Self>> {
        match lit {
            LitData::F32(v) => Some(v.clone()),
            LitData::I32(_) => None,
        }
    }
    const DTYPE: &'static str = "f32";
}

impl ArrayElement for i32 {
    fn wrap(data: Vec<Self>) -> LitData {
        LitData::I32(data)
    }
    fn unwrap(lit: &LitData) -> Option<Vec<Self>> {
        match lit {
            LitData::I32(v) => Some(v.clone()),
            LitData::F32(_) => None,
        }
    }
    const DTYPE: &'static str = "i32";
}

/// Dtype-tagged host storage backing a [`Literal`].
#[derive(Debug, Clone)]
pub enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl LitData {
    fn len(&self) -> usize {
        match self {
            LitData::F32(v) => v.len(),
            LitData::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            LitData::F32(_) => "f32",
            LitData::I32(_) => "i32",
        }
    }
}

/// A host literal: dtype-tagged data plus a logical shape.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LitData,
    shape: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: ArrayElement>(data: &[T]) -> Literal {
        Literal {
            shape: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Reshape without copying; element counts must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            shape: dims.to_vec(),
        })
    }

    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error(format!(
                "literal dtype mismatch: stored {}, requested {}",
                self.data.dtype(),
                T::DTYPE
            ))
        })
    }
}

/// A "device" buffer. The stub has no devices, so this is a host literal
/// behind the PJRT buffer API.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Parsed-enough HLO module: the stub keeps the text so diagnostics can
/// name the program, but cannot lower or run it.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    name: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("cannot read HLO text {}: {e}", path.display())))?;
        // first `HloModule <name>` token, else the file name
        let name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule "))
            .map(|rest| rest.split([',', ' ']).next().unwrap_or("").to_string())
            .unwrap_or_else(|| path.display().to_string());
        Ok(HloModuleProto { name })
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Computation handle produced from a proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            name: proto.name.clone(),
        }
    }
}

/// Compiled executable. Never constructed by the stub (compile fails), but
/// the type and its methods must exist for callers to typecheck.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(format!(
            "xla stub cannot execute '{}': rebuild against the real xla_extension backend",
            self.name
        )))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(format!(
            "xla stub cannot execute '{}': rebuild against the real xla_extension backend",
            self.name
        )))
    }
}

/// The CPU PJRT client. Construction succeeds (host-side plumbing is real);
/// compilation fails with a clear message.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(format!(
            "xla stub cannot compile '{}': the xla_extension backend is not present \
             in this build (see rust/vendor/xla)",
            comp.name
        )))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let shape: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
        Ok(PjRtBuffer {
            lit: Literal::vec1(data).reshape(&shape)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_uploads_but_does_not_compile() {
        let c = PjRtClient::cpu().unwrap();
        let b = c
            .buffer_from_host_buffer(&[1i32, 2, 3, 4, 5, 6], &[2, 3], None)
            .unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        let comp = XlaComputation::from_proto(&HloModuleProto {
            name: "m".into(),
        });
        assert!(c.compile(&comp).is_err());
    }
}
