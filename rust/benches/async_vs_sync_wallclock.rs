//! Bench: §8.2 architecture check on THIS testbed — the identical
//! coordinator code (controller, executors, channels, DDMA bus) run in both
//! modes over the real nano artifacts, wall-clock compared.
//!
//! NOTE on interpretation: this host has ONE core, so real PJRT compute
//! cannot overlap and the async win here comes only from pipelining slack
//! (it can even lose slightly to scheduling overhead). The cluster-scale
//! wall-clock claim is reproduced by the DES/cost-model benches; THIS bench
//! proves the coordinator machinery itself adds negligible overhead and
//! that its async data path (lag, backpressure, DDMA) behaves as designed
//! under real execution.

use llamarl::coordinator::{run_training, Mode, PipelineConfig};
use llamarl::util::bench::Table;

fn main() {
    if !std::path::Path::new("artifacts/nano/manifest.json").exists() {
        // the explicit marker lets CI logs distinguish "skipped" from
        // "ran and measured nothing"
        println!(
            "BENCH SKIPPED: artifacts/nano/manifest.json missing — run \
             `make artifacts` (or `python -m compile.aot --preset nano`) first"
        );
        std::process::exit(0);
    }
    println!("\n=== async vs sync wall-clock, real pipeline (nano artifacts) ===\n");
    let steps = 12u64;
    let base = PipelineConfig {
        artifact_dir: "artifacts/nano".into(),
        max_steps: steps,
        max_response: 10,
        n_generations: 4,
        eval_every: 0,
        ..PipelineConfig::default()
    };

    let sync = run_training(&PipelineConfig {
        mode: Mode::Sync,
        out_dir: std::env::temp_dir().join("llamarl_bench_sync"),
        ..base.clone()
    })
    .expect("sync run");

    let async1 = run_training(&PipelineConfig {
        mode: Mode::Async,
        n_generator_workers: 1,
        out_dir: std::env::temp_dir().join("llamarl_bench_async1"),
        ..base.clone()
    })
    .expect("async run");

    let async2 = run_training(&PipelineConfig {
        mode: Mode::Async,
        n_generator_workers: 2,
        out_dir: std::env::temp_dir().join("llamarl_bench_async2"),
        ..base
    })
    .expect("async run");

    let mut t = Table::new(&[
        "mode",
        "s/step",
        "tokens",
        "trajs",
        "mean lag",
        "ddma ms",
        "gen blocked s",
    ]);
    for r in [&sync, &async1, &async2] {
        let mean_lag = if r.records.is_empty() {
            0.0
        } else {
            r.records.iter().map(|x| x.mean_lag).sum::<f64>() / r.records.len() as f64
        };
        t.row(vec![
            format!(
                "{}{}",
                r.mode,
                if r.mode == "async" {
                    format!(" w={}", if std::ptr::eq(r, &async2) { 2 } else { 1 })
                } else {
                    String::new()
                }
            ),
            format!("{:.3}", r.mean_step_secs()),
            r.tokens_generated.to_string(),
            r.trajectories.to_string(),
            format!("{mean_lag:.2}"),
            format!("{:.2}", r.ddma_mean_publish_secs * 1e3),
            format!("{:.2}", r.gen_send_blocked_secs),
        ]);
    }
    t.print();

    println!(
        "\ntrainer compute share of sync step: {:.1}%  (the rest is the Fig-2 bubble)",
        100.0
            * sync.records.iter().map(|r| r.wall_secs).sum::<f64>()
            / sync.wall_secs.max(1e-9)
    );
    println!(
        "async off-policy lag: mean {:.2}, max {}",
        async2
            .records
            .iter()
            .map(|x| x.mean_lag)
            .sum::<f64>()
            / async2.records.len().max(1) as f64,
        async2.records.iter().map(|x| x.max_lag).max().unwrap_or(0)
    );
}
