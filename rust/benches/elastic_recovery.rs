//! Bench: elastic fleet recovery under a seeded chaos kill schedule.
//!
//! Two arms drive the identical synthetic rollout workload — a fleet of
//! worker threads decoding rows chunk by chunk into a real
//! [`RolloutStore`] while a consumer samples it down:
//!
//! * **unperturbed** — every worker runs clean under
//!   `RestartPolicy::Never`; this is the throughput ceiling;
//! * **chaos** — a seeded [`ChaosSchedule`] kills every worker twice,
//!   early in the attempt (1-3 chunks in, exactly what the runtime's
//!   injection hook does). The dying attempt parks its in-flight partial
//!   row in the store; [`supervise`] backs off and respawns; the
//!   replacement's first act is to reclaim a parked partial and finish it
//!   at the recorded chunk offset.
//!
//! Measured per kill: **recovery time** — restart hook to the replacement's
//! first admitted row (backoff + resume + remaining chunks). Measured per
//! arm: **rows/sec**, giving the throughput retained under churn.
//!
//! Shape checks (acceptance): no kill may escalate (the supervisor absorbs
//! the whole schedule), every restart lands (journal-equivalent count),
//! every parked partial is resumed exactly once (no lost and no duplicated
//! work), and the chaos arm retains a sane fraction of clean throughput.
//!
//! Emits `BENCH_elastic.json` (stdout line + target/BENCH_elastic.json;
//! gated against the committed repo-root baseline by tools/bench_gate.sh).
//!
//! CI smoke: `LLAMARL_BENCH_ROUNDS=3` caps the workload.

use std::cell::Cell;
use std::hint::black_box;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use llamarl::coordinator::graph::{supervise, ChaosSchedule, RestartPolicy};
use llamarl::data::{Difficulty, Problem, PromptTask};
use llamarl::dataplane::{PartialRollout, RolloutStore, StoreConfig};
use llamarl::rl::{FinishReason, Trajectory};
use llamarl::util::bench::{bench_rounds, fmt_secs};
use llamarl::util::json::Value;

const WORKERS: usize = 4;
const CHUNKS_PER_ROW: u64 = 4;
const KILLS_PER_WORKER: u64 = 2;
const CHAOS_SEED: u64 = 42;

/// A few hundred microseconds of real compute per decode chunk — the unit
/// of work a kill interrupts and a resume recovers.
fn decode_chunk(scratch: &mut [u64]) {
    let mut acc = 0x9E37u64;
    for w in scratch.iter_mut() {
        acc = acc.wrapping_add(*w).rotate_left(7);
        *w ^= acc;
    }
    black_box(acc);
}

fn fresh_partial(worker: usize, id: u64) -> PartialRollout {
    let prompt = vec![1, 2, 3];
    PartialRollout {
        task: PromptTask {
            // globally unique per (worker, row): parked partials never
            // collide in the store's resumption slot
            group_id: ((worker as u64) << 32) | id,
            replica: worker,
            n_replicas: WORKERS,
            problem: Problem {
                prompt: "2+2=".into(),
                answer: "4".into(),
                difficulty: Difficulty::Add1,
            },
            prompt_tokens: prompt.clone(),
        },
        prompt_len: prompt.len(),
        tokens: prompt,
        logps: Vec::new(),
        chunks: 0,
        gen_version: 0,
    }
}

fn finish_row(p: PartialRollout) -> Trajectory {
    Trajectory {
        group_id: p.task.group_id,
        replica: p.task.replica,
        n_replicas: p.task.n_replicas,
        problem: p.task.problem,
        prompt_tokens: p.task.prompt_tokens,
        response_tokens: p.tokens[p.prompt_len..].to_vec(),
        behavior_logp: p.logps,
        gen_version: p.gen_version,
        chunks: p.chunks,
        finish: FinishReason::Eos,
        reward: 0.0,
        advantage: 0.0,
    }
}

struct WorkerOut {
    rows: u64,
    restarts: u64,
    /// restart-hook -> first-admitted-row, one sample per restart that
    /// went on to admit anything
    recoveries: Vec<f64>,
    escalated: bool,
}

struct ArmResult {
    wall_secs: f64,
    rows: u64,
    restarts: u64,
    recoveries: Vec<f64>,
    escalations: u64,
    parked: u64,
    resumed: u64,
}

/// Drive the fleet to a fixed row quota per worker, with or without the
/// chaos schedule, and collect the recovery telemetry.
fn run_arm(rows_per_worker: u64, chaos: Option<ChaosSchedule>) -> ArmResult {
    let store = Arc::new(RolloutStore::new(StoreConfig {
        capacity: 256,
        max_staleness: None,
        ..StoreConfig::default()
    }));
    let done = Arc::new(AtomicBool::new(false));

    // consumer: keeps the store drained the way the trainer does
    let consumer = {
        let store = store.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            while !done.load(Ordering::Acquire) {
                let _ = store.sample(32, Duration::from_millis(5));
            }
        })
    };

    let policy = match chaos {
        Some(c) => RestartPolicy::BoundedRetries {
            max: c.max_kills_per_worker() as u32 + 1,
            backoff: Duration::from_millis(2),
        },
        None => RestartPolicy::Never,
    };

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..WORKERS {
        let store = store.clone();
        handles.push(std::thread::spawn(move || {
            let mut scratch = vec![1u64; 8 * 1024];
            let mut rows_done = 0u64;
            let mut next_id = 0u64;
            let mut restarts = 0u64;
            let mut recoveries = Vec::new();
            // written by the restart hook, read at the next admitted row
            let restart_at: Cell<Option<Instant>> = Cell::new(None);
            let r = supervise(
                policy,
                || false,
                |_, _, _| {
                    restarts += 1;
                    restart_at.set(Some(Instant::now()));
                },
                |attempt| {
                    let kill_after = chaos.and_then(|c| c.kill_after(w, attempt));
                    let mut chunks_this_attempt = 0u64;
                    while rows_done < rows_per_worker {
                        // a replacement reclaims parked work first — its own
                        // or a dead peer's — before starting fresh rows
                        let mut p = store.take_partial_any().unwrap_or_else(|| {
                            next_id += 1;
                            fresh_partial(w, next_id)
                        });
                        while u64::from(p.chunks) < CHUNKS_PER_ROW {
                            decode_chunk(&mut scratch);
                            p.tokens.push(7);
                            p.logps.push(-0.5);
                            p.chunks += 1;
                            chunks_this_attempt += 1;
                            if kill_after.is_some_and(|k| chunks_this_attempt >= k) {
                                // mirror the runtime's crash path: park the
                                // in-flight row for a survivor, then die
                                store.park_partial(p);
                                return Err(llamarl::Error::msg(format!(
                                    "chaos kill: worker {w} attempt {attempt}"
                                )));
                            }
                        }
                        store.push_group(vec![finish_row(p)])?;
                        rows_done += 1;
                        if let Some(at) = restart_at.take() {
                            recoveries.push(at.elapsed().as_secs_f64());
                        }
                    }
                    Ok(())
                },
            );
            WorkerOut {
                rows: rows_done,
                restarts,
                recoveries,
                escalated: r.is_err(),
            }
        }));
    }

    let outs: Vec<WorkerOut> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall_secs = t0.elapsed().as_secs_f64();
    done.store(true, Ordering::Release);
    store.close();
    consumer.join().unwrap();

    let snap = store.snapshot();
    ArmResult {
        wall_secs,
        rows: outs.iter().map(|o| o.rows).sum(),
        restarts: outs.iter().map(|o| o.restarts).sum(),
        recoveries: outs.iter().flat_map(|o| o.recoveries.iter().copied()).collect(),
        escalations: outs.iter().filter(|o| o.escalated).count() as u64,
        parked: snap.parked,
        resumed: snap.resumed,
    }
}

fn main() {
    println!("\n=== elastic recovery: supervised restarts under a chaos kill schedule ===\n");
    let rounds = bench_rounds(12);
    let rows_per_worker = rounds as u64 * 5;
    let kills = KILLS_PER_WORKER * WORKERS as u64;
    let chaos = ChaosSchedule::new(CHAOS_SEED, kills, WORKERS).expect("kills > 0");
    println!(
        "fleet: {WORKERS} workers x {rows_per_worker} rows ({CHUNKS_PER_ROW} chunks/row), \
         chaos: {kills} kills, seed {CHAOS_SEED}\n"
    );

    let base = run_arm(rows_per_worker, None);
    let churn = run_arm(rows_per_worker, Some(chaos));

    let base_rps = base.rows as f64 / base.wall_secs.max(1e-9);
    let churn_rps = churn.rows as f64 / churn.wall_secs.max(1e-9);
    let retained = churn_rps / base_rps.max(1e-9);
    let recovery_mean = if churn.recoveries.is_empty() {
        f64::INFINITY
    } else {
        churn.recoveries.iter().sum::<f64>() / churn.recoveries.len() as f64
    };
    let recovery_speed = if recovery_mean.is_finite() && recovery_mean > 0.0 {
        1.0 / recovery_mean
    } else {
        0.0
    };

    println!(
        "unperturbed: {} rows in {} ({:.0} rows/s)",
        base.rows,
        fmt_secs(base.wall_secs),
        base_rps
    );
    println!(
        "chaos:       {} rows in {} ({:.0} rows/s, {:.1}% retained)",
        churn.rows,
        fmt_secs(churn.wall_secs),
        churn_rps,
        retained * 100.0
    );
    println!(
        "recovery:    {} restarts, mean kill->first-row {} ({} partials parked, {} resumed)\n",
        churn.restarts,
        fmt_secs(recovery_mean),
        churn.parked,
        churn.resumed
    );

    // acceptance: the supervisor must absorb the WHOLE schedule (zero
    // escalations in either arm), land every scheduled restart, and lose
    // no parked work — every park resumed exactly once
    let no_global_stop = base.escalations == 0 && churn.escalations == 0;
    let restarts_complete = churn.restarts == kills && base.restarts == 0;
    let partials_migrated_ok = churn.parked >= 1 && churn.resumed == churn.parked;
    let rows_complete =
        base.rows == rows_per_worker * WORKERS as u64 && churn.rows == base.rows;
    println!(
        "shape checks: no escalation under chaos: {}; all {} scheduled kills \
         restarted: {}; parked == resumed (no lost work): {}; both arms hit \
         the full row quota: {}\n",
        if no_global_stop { "PASS" } else { "FAIL" },
        kills,
        if restarts_complete { "PASS" } else { "FAIL" },
        if partials_migrated_ok { "PASS" } else { "FAIL" },
        if rows_complete { "PASS" } else { "FAIL" },
    );

    let json = Value::object(vec![
        ("rounds", Value::num(rounds as f64)),
        ("workers", Value::num(WORKERS as f64)),
        ("rows_per_worker", Value::num(rows_per_worker as f64)),
        ("chaos_kills", Value::num(kills as f64)),
        ("chaos_seed", Value::num(CHAOS_SEED as f64)),
        ("base_rows_per_sec", Value::num(base_rps)),
        ("chaos_rows_per_sec", Value::num(churn_rps)),
        ("throughput_retained_frac", Value::num(retained)),
        // JSON has no Infinity: a no-recovery run (restarts_complete
        // already FAIL) emits 0 here and 0 speed below
        (
            "recovery_secs_mean",
            Value::num(if recovery_mean.is_finite() { recovery_mean } else { 0.0 }),
        ),
        ("recovery_speed", Value::num(recovery_speed)),
        ("restarts", Value::num(churn.restarts as f64)),
        ("partials_parked", Value::num(churn.parked as f64)),
        ("partials_resumed", Value::num(churn.resumed as f64)),
        ("no_global_stop", Value::Bool(no_global_stop)),
        ("restarts_complete", Value::Bool(restarts_complete)),
        ("partials_migrated_ok", Value::Bool(partials_migrated_ok)),
        ("rows_complete", Value::Bool(rows_complete)),
    ]);
    llamarl::util::bench::emit_summary("BENCH_elastic.json", &json);
}
