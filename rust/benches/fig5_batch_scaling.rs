//! Bench: paper **Figure 5** — empirical verification of Assumption 7.1
//! (per-sample processing time decreases monotonically with batch size).
//!
//! Two panels, as in the paper:
//!   left  — training time per fixed sample count vs microbatch size
//!   right — generation time per fixed completion count vs decode
//!           concurrency
//!
//! These are REAL measurements against the fig5_* artifact variants of the
//! `small` config (the same train_step/generate_chunk graphs at b in
//! {1,2,4,8,16}), executed through PJRT exactly as the training pipeline
//! runs them. The cost-model curve for the 70B paper point is printed
//! alongside for comparison.

use llamarl::model::load_init_params;
use llamarl::runtime::{HostTensor, Runtime};
use llamarl::simulator::hardware::{calibrated_eta, HardwareModel, LLAMA_MODELS};
use llamarl::util::bench::{fmt_secs, time_fn, Table};
use llamarl::util::stats::summarize;

const SAMPLES_PER_POINT: usize = 32; // fixed work per row (paper: 128 / 64)

fn main() {
    let dir = "artifacts/small";
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        // the explicit marker lets CI logs distinguish "skipped" from
        // "ran and measured nothing"
        println!(
            "BENCH SKIPPED: {dir}/manifest.json missing — run `make artifacts` \
             (or `python -m compile.aot --preset small`) first"
        );
        std::process::exit(0);
    }
    let rt = Runtime::load(dir).expect("load artifacts");
    let m = rt.manifest.clone();
    let params = load_init_params(&m).unwrap();

    println!("\n=== Figure 5 (left): train time per {SAMPLES_PER_POINT} samples vs microbatch ===\n");
    let mut t = Table::new(&["microbatch b", "time/32 samples", "eta_t(b) per-sample", "monotone?"]);
    let mut last = f64::INFINITY;
    let mut train_etas = Vec::new();
    for &b in &m.fig5_train_batches {
        let name = format!("fig5_train_b{b}");
        let art = m.artifact(&name).expect("fig5 artifact");
        let t_dim = art.inputs[1].shape[1];
        let total = m.train_state.total;
        let mut state = params.clone();
        state.resize(total, 0.0);
        let state_b = rt.upload(&HostTensor::F32(state, vec![total])).unwrap();
        let tokens: Vec<i32> = (0..b * t_dim).map(|i| (i % 40 + 3) as i32).collect();
        let targets: Vec<i32> = (0..b * t_dim).map(|i| ((i + 1) % 40 + 3) as i32).collect();
        let inputs = [
            rt.upload(&HostTensor::I32(tokens, vec![b, t_dim])).unwrap(),
            rt.upload(&HostTensor::I32(targets, vec![b, t_dim])).unwrap(),
            rt.upload(&HostTensor::F32(vec![-2.0; b * t_dim], vec![b, t_dim])).unwrap(),
            rt.upload(&HostTensor::F32(vec![0.1; b * t_dim], vec![b, t_dim])).unwrap(),
            rt.upload(&HostTensor::F32(vec![1.0; b * t_dim], vec![b, t_dim])).unwrap(),
            rt.upload(&HostTensor::I32(vec![t_dim as i32; b], vec![b])).unwrap(),
            rt.upload(&HostTensor::F32(vec![1e-4, 4.0, 1.0], vec![3])).unwrap(),
        ];
        let samples = time_fn(1, 5, || {
            let out = rt
                .execute_buffers(
                    &name,
                    &[
                        &state_b, &inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4],
                        &inputs[5], &inputs[6],
                    ],
                )
                .unwrap();
            std::hint::black_box(&out);
        });
        let per_call = summarize(&samples).p50;
        let per_sample = per_call / b as f64;
        let fixed_work = per_sample * SAMPLES_PER_POINT as f64;
        train_etas.push(per_sample);
        let mono = per_sample <= last * 1.10; // allow 10% measurement noise
        last = per_sample;
        t.row(vec![
            b.to_string(),
            fmt_secs(fixed_work),
            fmt_secs(per_sample),
            if mono { "yes".into() } else { "NO".into() },
        ]);
    }
    t.print();

    println!("\n=== Figure 5 (right): generation time per {SAMPLES_PER_POINT} completions vs concurrency ===\n");
    let mut g = Table::new(&["concurrency b", "time/32 compl.", "eta_g(b) per-compl.", "monotone?"]);
    let mut lastg = f64::INFINITY;
    let mut gen_etas = Vec::new();
    for &b in &m.fig5_gen_batches {
        let name = format!("fig5_gen_b{b}");
        let art = m.artifact(&name).expect("fig5 artifact");
        let s_dim = art.inputs[1].shape[1];
        let params_b = rt
            .upload(&HostTensor::F32(params.clone(), vec![m.num_params]))
            .unwrap();
        let mut tokens = vec![0i32; b * s_dim];
        for row in 0..b {
            tokens[row * s_dim] = 1; // BOS
            for j in 1..6 {
                tokens[row * s_dim + j] = (3 + j) as i32;
            }
        }
        let inputs = [
            rt.upload(&HostTensor::I32(tokens, vec![b, s_dim])).unwrap(),
            rt.upload(&HostTensor::I32(vec![6; b], vec![b])).unwrap(),
            rt.upload(&HostTensor::I32(vec![0; b], vec![b])).unwrap(),
            rt.upload(&HostTensor::I32(vec![7], vec![1])).unwrap(),
            rt.upload(&HostTensor::F32(vec![1.0], vec![1])).unwrap(),
            rt.upload(&HostTensor::I32(vec![0], vec![1])).unwrap(),
        ];
        let samples = time_fn(1, 5, || {
            let out = rt
                .execute_buffers(
                    &name,
                    &[
                        &params_b, &inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4],
                        &inputs[5],
                    ],
                )
                .unwrap();
            std::hint::black_box(&out);
        });
        let per_call = summarize(&samples).p50;
        let per_completion = per_call / b as f64;
        gen_etas.push(per_completion);
        let mono = per_completion <= lastg * 1.10;
        lastg = per_completion;
        g.row(vec![
            b.to_string(),
            fmt_secs(per_completion * SAMPLES_PER_POINT as f64),
            fmt_secs(per_completion),
            if mono { "yes".into() } else { "NO".into() },
        ]);
    }
    g.print();

    // amortization ratios (first/last): how much batch helps
    if let (Some(f), Some(l)) = (train_etas.first(), train_etas.last()) {
        println!("\ntrain eta(1)/eta(max) = {:.2}x amortization", f / l);
    }
    if let (Some(f), Some(l)) = (gen_etas.first(), gen_etas.last()) {
        println!("gen   eta(1)/eta(max) = {:.2}x amortization", f / l);
    }

    println!("\n--- calibrated 70B cost-model curve (paper panel) ---\n");
    let hw = HardwareModel::paper_scale(LLAMA_MODELS[1]);
    let p = hw.problem();
    let (_et, _eg) = calibrated_eta(1.0); // shape illustration at unit anchor
    let mut c = Table::new(&["b", "eta_t(b) s", "eta_g(b) s"]);
    for b in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0] {
        c.row(vec![
            format!("{b}"),
            format!("{:.3}", (p.eta_t)(b)),
            format!("{:.3}", (p.eta_g)(b)),
        ]);
    }
    c.print();
    println!(
        "\nInterpretation: Assumption 7.1 is a statement about PARALLEL hardware\n\
         (batch amortizes idle compute units — paper Fig. 5 on H100s, and the\n\
         calibrated curve above). A single saturated CPU core has no idle\n\
         units to harvest, so the real measurement shows eta flattening after\n\
         the small-batch dispatch overhead is amortized (b=1 -> 2) and then\n\
         RISING from cache pressure — i.e. the assumption's mechanism, not a\n\
         contradiction of it. The cost-model curve is what enters the\n\
         Table-3/Theorem-7.5 reproduction."
    );
}
