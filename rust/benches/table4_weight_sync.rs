//! Bench: paper **Table 4** — weight-synchronization time, OpenRLHF's
//! parameter-server path vs DDMA.
//!
//! Three layers of evidence:
//!   1. the calibrated cluster models (PS power law through OpenRLHF's
//!      published points; DDMA shard model through the paper's points),
//!   2. the paper's ">900 s at 405B" PS extrapolation,
//!   3. REAL measurements of this repo's in-process DDMA handoff (sharded
//!      snapshot copy + bus publish + subscriber attach) across sizes.

use llamarl::ddma::ps_baseline::PsModel;
use llamarl::ddma::topology::DdmaModel;
use llamarl::ddma::{sharded_copy, WeightsBus};
use llamarl::util::bench::{fmt_secs, time_fn, Table};
use llamarl::util::stats::summarize;

fn main() {
    println!("\n=== Table 4: weight synchronization time (seconds) ===\n");
    let ddma = DdmaModel::calibrated();
    let ps = PsModel::calibrated();

    let mut t = Table::new(&[
        "model",
        "paper PS",
        "model PS",
        "paper DDMA",
        "model DDMA",
        "DDMA floor",
    ]);
    let rows: [(&str, f64, usize, Option<f64>, Option<f64>); 3] = [
        ("7B", 7e9, 128, Some(4.32), Some(0.04)),
        ("70B", 70e9, 128, Some(111.65), Some(1.15)),
        ("405B", 405e9, 512, None, Some(2.31)),
    ];
    for (name, params, gpus, ps_paper, ddma_paper) in rows {
        t.row(vec![
            name.into(),
            ps_paper.map(|x| format!("{x:.2}")).unwrap_or("-".into()),
            format!("{:.2}", ps.sync_secs(params)),
            ddma_paper.map(|x| format!("{x:.2}")).unwrap_or("-".into()),
            format!("{:.2}", ddma.sync_secs(params, gpus)),
            format!("{:.4}", ddma.floor_secs(params, gpus)),
        ]);
    }
    t.print();
    println!(
        "\npaper: PS at 405B estimated >900 s; model extrapolates {:.0} s\n",
        ps.sync_secs(405e9)
    );

    println!("--- real in-process DDMA handoff (this testbed) ---\n");
    let mut rt = Table::new(&["params", "bytes", "sharded copy", "publish+attach", "GB/s"]);
    for p in [29_312usize, 655_744, 3_352_064, 16_777_216] {
        let src: Vec<f32> = (0..p).map(|i| i as f32 * 0.001).collect();
        let copy_samples = time_fn(2, 10, || {
            let c = sharded_copy(&src, 16);
            std::hint::black_box(c.data.len());
        });
        let bus = WeightsBus::new(src.clone());
        let publish_samples = time_fn(2, 10, || {
            let v = bus.publish(src.clone());
            let snap = bus.latest();
            std::hint::black_box((v, snap.data[0]));
        });
        let cs = summarize(&copy_samples);
        let pubs = summarize(&publish_samples);
        let bytes = p * 4;
        rt.row(vec![
            format!("{p}"),
            format!("{:.1}MB", bytes as f64 / 1e6),
            fmt_secs(cs.p50),
            fmt_secs(pubs.p50),
            format!("{:.2}", bytes as f64 / cs.p50.max(1e-12) / 1e9),
        ]);
    }
    rt.print();
    println!(
        "\nShape checks: DDMA is 2-3 orders of magnitude below PS at every size;\n\
         DDMA time is constant at fixed shard size (linear scalability);\n\
         PS grows superlinearly with model size."
    );
}
