//! Bench: the colocated offloading memory plane — eager offload vs
//! background overlapped prefetch vs no colocation.
//!
//! Panel 1 (planner): per-phase placements the colocation planner proves
//! for a testbed-scale spec, plus the loud capacity rejection of a spec
//! that cannot fit its rank (acceptance: infeasible colocations must fail
//! before running, never OOM mid-step).
//!
//! Panel 2 (real, this testbed): three arms drive the identical phase
//! schedule — lease(Generate) + decode walk, hint(Train), lease(Train) +
//! per-shard optimizer walk — against real shard arenas:
//!
//! * **no-colocation** — disjoint ranks, nothing moves; leases are pure
//!   accounting (the floor, at the price of twice the ranks);
//! * **eager offload** — colocated, no background executor: every lease
//!   pays its full D2H/H2D stream synchronously;
//! * **overlapped prefetch** — colocated + background executor: the D2H
//!   drain interleaves with KV growth behind decode, the Train hint
//!   streams optimizer shards back during generation, and the per-shard
//!   walk overlaps the remainder. Blocked time is what's left.
//!
//! Shape check (acceptance): the overlapped arm must hide >= 70% of the
//! eager arm's blocked transfer time, and shard integrity must hold.
//!
//! Panel 3 (DES, 70B paper scale): the planner's flip costs on the
//! calibrated PCIe link feed the sync-architecture timeline — eager
//! offload vs overlapped vs no colocation.
//!
//! Emits `BENCH_offload.json` (stdout line + target/BENCH_offload.json;
//! gated against the committed repo-root baseline by tools/bench_gate.sh).
//!
//! CI smoke: `LLAMARL_BENCH_ROUNDS=3` caps the measured rounds.

use std::hint::black_box;

use llamarl::ddma::topology::DdmaModel;
use llamarl::memplane::plan::{plan_colocation, Phase, Residency};
use llamarl::memplane::pool::{AllocClass, MemSpec};
use llamarl::memplane::{MemPlane, MemPlaneConfig};
use llamarl::simulator::hardware::{HardwareModel, LLAMA_MODELS};
use llamarl::simulator::{simulate_timeline, DesConfig};
use llamarl::util::bench::{bench_rounds, fmt_secs, Table};
use llamarl::util::json::Value;

const MB: u64 = 1_000_000;
const SHARDS: usize = 8;

/// Testbed-scale spec: optimizer state dominates (the realistic shape),
/// KV large enough that the generate phase cannot keep it resident.
fn spec() -> MemSpec {
    MemSpec::new(24 * MB, 24 * MB, 48 * MB, 64 * MB, 24 * MB)
}

/// Device capacity that admits each phase but NOT the retained union:
/// colocation must actually offload (train set 120 MB, generate-with-
/// optimizer 160 MB > 136 MB).
const DEVICE_CAP: u64 = 136 * MB;

/// A few milliseconds of real compute (decode chunk / optimizer shard
/// update): the work the background transfers hide behind.
fn compute(scratch: &mut [u64], passes: usize) {
    for p in 0..passes {
        let mut acc = p as u64;
        for w in scratch.iter_mut() {
            acc = acc.wrapping_add(*w).rotate_left(7);
            *w ^= acc;
        }
        black_box(acc);
    }
}

fn plane_cfg(colocate: bool, background: bool) -> MemPlaneConfig {
    MemPlaneConfig {
        colocate,
        background,
        offload_classes: vec![AllocClass::Grads, AllocClass::OptimState],
        offload_chunk_mb: 4,
        prefetch_depth: SHARDS,
        shards_per_class: SHARDS,
        device_bytes: if colocate { DEVICE_CAP } else { 0 },
        host_bytes: 512 * MB,
        concurrent_phases: false,
    }
}

struct ArmResult {
    name: &'static str,
    /// lease + wait_shard blocked seconds per round
    blocked_per_round: f64,
    transferred_mb_per_round: f64,
    prefetch_hits: u64,
    superseded: u64,
    integrity_ok: bool,
}

/// Drive `rounds` of the generate -> train phase schedule on one plane.
fn run_arm(name: &'static str, colocate: bool, background: bool, rounds: usize) -> ArmResult {
    let plane = MemPlane::new(spec(), &plane_cfg(colocate, background)).expect("feasible plan");
    let mut scratch = vec![1u64; (8 * MB / 8) as usize];
    for _ in 0..rounds {
        {
            let g = plane.lease(Phase::Generate).expect("generate lease");
            // arm the prefetcher for the coming train phase: optimizer
            // shards stream back behind the decode walk below
            plane.hint_next(Phase::Train);
            for s in 0..SHARDS {
                // KV grows shard by shard as the offload drain frees HBM
                g.wait_shard(AllocClass::KvCache, s).expect("kv shard");
                compute(&mut scratch, 2); // one decode chunk
            }
        }
        {
            let t = plane.lease(Phase::Train).expect("train lease");
            for s in 0..SHARDS {
                // fence, then update: shard s+1 streams while s computes
                t.wait_shard(AllocClass::OptimState, s).expect("optim shard");
                compute(&mut scratch, 1); // one optimizer shard update
            }
            t.wait_class(AllocClass::Grads).expect("grads resident");
        }
    }
    plane.flush().expect("converge");
    let m = plane.metrics();
    use std::sync::atomic::Ordering::Relaxed;
    ArmResult {
        name,
        blocked_per_round: m.wait_secs() / rounds as f64,
        transferred_mb_per_round: m.transferred_bytes() as f64 / rounds as f64 / 1e6,
        prefetch_hits: m.prefetch_hits.load(Relaxed),
        superseded: m.superseded_targets.load(Relaxed),
        integrity_ok: plane.verify_integrity().is_ok(),
    }
}

fn panel_planner() -> bool {
    println!("--- panel 1: colocation planner placements + capacity rejection ---\n");
    let s = spec();
    let plan = plan_colocation(
        s,
        DEVICE_CAP,
        512 * MB,
        true,
        false,
        &[AllocClass::Grads, AllocClass::OptimState],
    )
    .expect("feasible");
    let mut t = Table::new(&["class", "MB", "generate", "train", "sync"]);
    for c in AllocClass::ALL {
        let cell = |p: Phase| match plan.residency(p, c) {
            Residency::Device => "device",
            Residency::Host => "HOST",
            Residency::Dropped => "dropped",
        };
        t.row(vec![
            c.name().into(),
            (s.bytes(c) / MB).to_string(),
            cell(Phase::Generate).into(),
            cell(Phase::Train).into(),
            cell(Phase::Sync).into(),
        ]);
    }
    t.print();
    println!(
        "\nper-rank HBM: {} MB cap, peak phase demand {} MB (union would \
         need {} MB — colocation earns its keep)",
        DEVICE_CAP / MB,
        plan.max_phase_device_bytes() / MB,
        s.total() / MB
    );

    // acceptance: a colocated placement that exceeds per-rank HBM must
    // fail with a capacity error rather than run
    let too_small = MemPlane::new(
        s,
        &MemPlaneConfig {
            device_bytes: 100 * MB, // train needs 120 even with kv dropped
            ..plane_cfg(true, true)
        },
    );
    let capacity_error_raised = matches!(
        &too_small,
        Err(llamarl::Error::Capacity(_))
    );
    println!(
        "infeasible colocation (100 MB rank): {}\n",
        match &too_small {
            Err(e) => format!("rejected loudly — {e}"),
            Ok(_) => "ACCEPTED (BUG)".into(),
        }
    );
    capacity_error_raised
}

fn panel_des() -> (f64, f64) {
    println!("--- panel 3: DES timeline, 70B colocated rank (paper scale) ---\n");
    let hw = HardwareModel::paper_scale(LLAMA_MODELS[1]); // 70B
    // mp 8, microbatch 6, decode concurrency 128: each phase fits an H100
    // rank, the retained union does not — the colocated regime
    let s = MemSpec::paper_rank(&hw, 8.0, 6.0, 128.0);
    let model = DdmaModel::calibrated();
    let plan = plan_colocation(
        s,
        hw.gpu.mem_bytes as u64,
        u64::MAX,
        true,
        false,
        &[AllocClass::Grads, AllocClass::OptimState],
    )
    .expect("70B colocated rank fits with offload");
    let (d2h, h2d) = plan.des_offload_costs(&model, 64);
    println!(
        "planned flips: offload {:.0} MB -> {}, prefetch {:.0} MB -> {}",
        plan.flip_bytes(Phase::Train, Phase::Generate).0 as f64 / 1e6,
        fmt_secs(d2h),
        plan.flip_bytes(Phase::Generate, Phase::Train).1 as f64 / 1e6,
        fmt_secs(h2d),
    );
    let base = DesConfig {
        steps: 100,
        offload_d2h_secs: d2h,
        offload_h2d_secs: h2d,
        ..DesConfig::default()
    };
    let (eager, _) = simulate_timeline(&base);
    let (overlapped, _) = simulate_timeline(&DesConfig {
        offload_overlap: true,
        ..base.clone()
    });
    let (none, _) = simulate_timeline(&DesConfig {
        offload_d2h_secs: 0.0,
        offload_h2d_secs: 0.0,
        ..base
    });
    let mut t = Table::new(&["arm", "s/step", "vs no-colocation"]);
    for (name, r) in [
        ("no colocation (2x ranks)", &none),
        ("colocated, eager offload", &eager),
        ("colocated, overlapped prefetch", &overlapped),
    ] {
        t.row(vec![
            name.into(),
            format!("{:.2}", r.step_secs_mean),
            format!("{:.3}x", r.total_secs / none.total_secs),
        ]);
    }
    t.print();
    println!();
    (eager.step_secs_mean, overlapped.step_secs_mean)
}

fn main() {
    println!("\n=== colocated offloading: eager vs overlapped prefetch vs none ===\n");
    let capacity_error_raised = panel_planner();

    let rounds = bench_rounds(10);
    println!("--- panel 2: measured phase schedule ({rounds} rounds, {SHARDS} shards/class) ---\n");
    let no_colo = run_arm("no colocation", false, true, rounds);
    let eager = run_arm("eager offload", true, false, rounds);
    let overlap = run_arm("overlapped prefetch", true, true, rounds);

    let mut t = Table::new(&[
        "arm",
        "blocked/round",
        "moved MB/round",
        "prefetch hits",
        "superseded",
        "integrity",
    ]);
    for a in [&no_colo, &eager, &overlap] {
        t.row(vec![
            a.name.into(),
            fmt_secs(a.blocked_per_round),
            format!("{:.1}", a.transferred_mb_per_round),
            a.prefetch_hits.to_string(),
            a.superseded.to_string(),
            if a.integrity_ok { "bit".into() } else { "CORRUPT".into() },
        ]);
    }
    t.print();

    let hidden_frac = 1.0 - overlap.blocked_per_round / eager.blocked_per_round.max(1e-12);
    let hides_70 = hidden_frac >= 0.70;
    let integrity_ok = no_colo.integrity_ok && eager.integrity_ok && overlap.integrity_ok;
    // eager round-trips the whole optimizer (48 MB each way); the
    // overlapped arm's hint-keep drains only what KV growth actually
    // displaces, so it must move real volume but never more than eager
    let moved_ok = eager.transferred_mb_per_round > 90.0
        && overlap.transferred_mb_per_round > 40.0
        && overlap.transferred_mb_per_round <= eager.transferred_mb_per_round + 1e-9
        && no_colo.transferred_mb_per_round < 1.0;
    println!(
        "\nshape checks: overlapped prefetch hides {:.1}% of eager blocked \
         transfer time (>= 70%): {}; capacity error raised on oversized \
         colocation: {}; shard integrity across all arms: {}; transfer \
         volumes sane (eager full, overlap partial-but-real, no-colocation \
         none): {}\n",
        hidden_frac * 100.0,
        if hides_70 { "PASS" } else { "FAIL" },
        if capacity_error_raised { "PASS" } else { "FAIL" },
        if integrity_ok { "PASS" } else { "FAIL" },
        if moved_ok { "PASS" } else { "FAIL" },
    );

    let (des_eager, des_overlap) = panel_des();

    let json = Value::object(vec![
        ("rounds", Value::num(rounds as f64)),
        ("shards_per_class", Value::num(SHARDS as f64)),
        ("device_cap_mb", Value::num((DEVICE_CAP / MB) as f64)),
        ("spec_total_mb", Value::num((spec().total() / MB) as f64)),
        (
            "no_colo_blocked_secs",
            Value::num(no_colo.blocked_per_round),
        ),
        ("eager_blocked_secs", Value::num(eager.blocked_per_round)),
        (
            "overlap_blocked_secs",
            Value::num(overlap.blocked_per_round),
        ),
        ("prefetch_hidden_frac", Value::num(hidden_frac)),
        (
            "eager_moved_mb",
            Value::num(eager.transferred_mb_per_round),
        ),
        (
            "overlap_moved_mb",
            Value::num(overlap.transferred_mb_per_round),
        ),
        (
            "overlap_prefetch_hits",
            Value::num(overlap.prefetch_hits as f64),
        ),
        (
            "overlap_superseded",
            Value::num(overlap.superseded as f64),
        ),
        ("des_70b_eager_step_secs", Value::num(des_eager)),
        ("des_70b_overlap_step_secs", Value::num(des_overlap)),
        ("prefetch_hides_70pct", Value::Bool(hides_70)),
        ("capacity_error_raised", Value::Bool(capacity_error_raised)),
        ("integrity_ok", Value::Bool(integrity_ok)),
        ("moved_full_volume", Value::Bool(moved_ok)),
    ]);
    llamarl::util::bench::emit_summary("BENCH_offload.json", &json);
}
