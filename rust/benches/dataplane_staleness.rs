//! Bench: the trajectory data plane — direct-channel async vs buffered
//! async over the RolloutStore, on throughput and realized off-policy lag.
//!
//! Panel 1 (DES): lag-matched comparison — the channel bounds lag only via
//! queue depth (throttling the generator); the store bounds it explicitly
//! via max-staleness drops while the generator free-runs.
//!
//! Panel 2 (threads): the synthetic driver pushes real trajectories from
//! real producer threads through both transports and reports rows/s plus
//! the realized lag distribution, including the sampling-strategy arms.
//!
//! Panel 3 (hot path): raw store push/sample cost per row vs the channel.

use std::time::Duration;

use llamarl::coordinator::channel::{gather_channel, Message};
use llamarl::dataplane::{
    run_driver, AdmissionPolicy, DriverConfig, RolloutStore, SamplingStrategy, StoreConfig,
    Transport,
};
use llamarl::data::{Difficulty, Problem};
use llamarl::rl::{FinishReason, Trajectory};
use llamarl::simulator::des::simulate_async;
use llamarl::simulator::{simulate_async_buffered, BufferedDesConfig, DesConfig};
use llamarl::util::bench::{bench, bench_rounds, Table};

fn traj(group_id: u64, gen_version: u64) -> Trajectory {
    Trajectory {
        group_id,
        replica: 0,
        n_replicas: 1,
        problem: Problem {
            prompt: "1+1=".into(),
            answer: "2".into(),
            difficulty: Difficulty::Add1,
        },
        prompt_tokens: vec![1, 2, 3, 4],
        response_tokens: vec![5, 6, 7],
        behavior_logp: vec![-0.5; 3],
        gen_version,
        chunks: 1,
        finish: FinishReason::Eos,
        reward: 1.0,
        advantage: 0.5,
    }
}

fn panel_des() -> (bool, bool) {
    println!("--- panel 1: DES, lag-matched channel vs store (gen_sigma=1.0) ---\n");
    let mut t = Table::new(&[
        "lag bound",
        "channel s/step",
        "store s/step",
        "store/channel",
        "channel lag",
        "store lag",
        "store drops",
    ]);
    let mut store_never_slower = true;
    let mut lag_always_bounded = true;
    for bound in [1usize, 2, 4] {
        let (mut ch_tot, mut st_tot, mut ch_lag, mut st_lag, mut st_max, mut drops) =
            (0.0, 0.0, 0.0, 0.0, 0.0f64, 0usize);
        let seeds = bench_rounds(5); // CI smoke: LLAMARL_BENCH_ROUNDS caps
        for seed in 0..seeds as u64 {
            let cfg = DesConfig {
                steps: 200,
                gen_sigma: 1.0,
                seed,
                ..DesConfig::default()
            };
            let ch = simulate_async(&DesConfig {
                queue_capacity: bound,
                ..cfg.clone()
            });
            let st = simulate_async_buffered(
                &cfg,
                &BufferedDesConfig {
                    store_capacity: 8,
                    max_staleness: bound as u64,
                    freshest_first: false,
                },
            );
            ch_tot += ch.total_secs;
            st_tot += st.total_secs;
            ch_lag += ch.mean_lag_steps;
            st_lag += st.mean_lag_steps;
            st_max = st_max.max(st.max_lag_steps);
            drops += st.dropped_batches;
        }
        let n = seeds as f64;
        store_never_slower &= st_tot <= ch_tot * 1.02;
        lag_always_bounded &= st_max <= bound as f64 + 1e-9;
        t.row(vec![
            bound.to_string(),
            format!("{:.2}", ch_tot / n / 200.0),
            format!("{:.2}", st_tot / n / 200.0),
            format!("{:.3}x", st_tot / ch_tot),
            format!("{:.2}", ch_lag / n),
            format!("{:.2}", st_lag / n),
            format!("{}", drops / seeds),
        ]);
    }
    t.print();
    println!(
        "\nshape check: store throughput >= lag-matched channel: {}; \
         realized max lag <= bound: {}",
        if store_never_slower { "PASS" } else { "FAIL" },
        if lag_always_bounded { "PASS" } else { "FAIL" },
    );
    (store_never_slower, lag_always_bounded)
}

fn panel_threads() -> (f64, f64, bool) {
    println!("\n--- panel 2: threaded driver, real transports (40 steps, 2 producers) ---\n");
    let base = DriverConfig {
        train_steps: bench_rounds(40) as u64,
        ..DriverConfig::default()
    };
    let bound = 4u64;
    let store = |sampling: SamplingStrategy, admission: AdmissionPolicy| {
        Transport::Store(StoreConfig {
            capacity: 64,
            shards: 4,
            max_staleness: Some(bound),
            admission,
            sampling,
            seed: 0,
        })
    };
    let mut t = Table::new(&[
        "transport",
        "rows/s",
        "mean lag",
        "max sampled lag",
        "dropped",
        "evicted",
    ]);
    let mut channel_rate = 0.0;
    let mut store_fifo_rate = 0.0;
    let mut bound_ok = true;
    for (i, transport) in [
        Transport::Channel { capacity: 4 },
        store(SamplingStrategy::Fifo, AdmissionPolicy::EvictOldest),
        store(SamplingStrategy::FreshestFirst, AdmissionPolicy::EvictOldest),
        store(SamplingStrategy::StalenessWeighted, AdmissionPolicy::EvictOldest),
        store(SamplingStrategy::Fifo, AdmissionPolicy::Block),
    ]
    .into_iter()
    .enumerate()
    {
        let r = run_driver(&DriverConfig {
            transport,
            ..base.clone()
        });
        let (max_sampled, dropped, evicted) = r
            .dataplane
            .as_ref()
            .map(|d| {
                bound_ok &= d.max_sampled_lag <= bound;
                (
                    d.max_sampled_lag.to_string(),
                    (d.dropped_stale + d.dropped_capacity).to_string(),
                    d.evicted.to_string(),
                )
            })
            .unwrap_or_else(|| ("-".into(), "-".into(), "-".into()));
        if i == 0 {
            channel_rate = r.rows_per_sec;
        }
        if i == 1 {
            store_fifo_rate = r.rows_per_sec;
        }
        t.row(vec![
            r.transport.clone(),
            format!("{:.0}", r.rows_per_sec),
            format!("{:.2}", r.mean_lag),
            max_sampled,
            dropped,
            evicted,
        ]);
    }
    t.print();
    println!(
        "\nshape check: store(fifo) throughput {:.0} rows/s vs channel {:.0} ({}); \
         sampled lag <= bound {bound}: {}",
        store_fifo_rate,
        channel_rate,
        if store_fifo_rate >= channel_rate * 0.9 {
            "PASS"
        } else {
            "FAIL"
        },
        if bound_ok { "PASS" } else { "FAIL" },
    );
    (channel_rate, store_fifo_rate, bound_ok)
}

fn panel_hot_path() {
    println!("\n--- panel 3: raw data-plane hot path (per-row cost) ---\n");
    let rows = 256usize;

    let store = RolloutStore::new(StoreConfig {
        capacity: rows,
        shards: 4,
        max_staleness: None,
        admission: AdmissionPolicy::EvictOldest,
        sampling: SamplingStrategy::Fifo,
        seed: 0,
    });
    let r = bench("store push+sample (256 rows, 4 shards)", 3, bench_rounds(20), || {
        for i in 0..rows as u64 {
            store.push_group(vec![traj(i, 0)]).unwrap();
        }
        let mut got = 0;
        while got < rows {
            got += store
                .sample(32, Duration::from_millis(1))
                .map(|v| v.len())
                .unwrap_or(0);
        }
    });
    r.print();

    let r = bench("channel send+recv (256 rows)", 3, bench_rounds(20), || {
        let (tx, rx) = gather_channel("bench", rows + 1);
        for i in 0..rows as u64 {
            tx.send(Message::Scored(vec![traj(i, 0)])).unwrap();
        }
        let mut got = 0;
        while got < rows {
            if let Some(Message::Scored(v)) = rx.try_recv() {
                got += v.len();
            }
        }
    });
    r.print();
}

fn main() {
    println!("\n=== data plane: staleness-aware store vs direct channel ===\n");
    let (store_never_slower, lag_always_bounded) = panel_des();
    let (channel_rate, store_fifo_rate, sampled_lag_bounded) = panel_threads();
    panel_hot_path();

    // machine-readable summary for the CI artifact upload, mirroring
    // BENCH_weightsync.json
    let json = llamarl::util::json::Value::object(vec![
        (
            "channel_rows_per_sec",
            llamarl::util::json::Value::num(channel_rate),
        ),
        (
            "store_fifo_rows_per_sec",
            llamarl::util::json::Value::num(store_fifo_rate),
        ),
        (
            "store_never_slower",
            llamarl::util::json::Value::Bool(store_never_slower),
        ),
        (
            "lag_always_bounded",
            llamarl::util::json::Value::Bool(lag_always_bounded),
        ),
        (
            "sampled_lag_bounded",
            llamarl::util::json::Value::Bool(sampled_lag_bounded),
        ),
    ]);
    llamarl::util::bench::emit_summary("BENCH_dataplane.json", &json);
}
