//! Bench: paper **Figure 7** — efficiency gain of LlamaRL over the
//! synchronous baseline vs model scale (log-x), including an extrapolation
//! beyond 405B (the paper's "suitability for future large-scale training"
//! claim).
//!
//! Gain = paper-config baseline replay / optimizer's best async config,
//! identical hardware budget (same convention as the Table-3 bench).

use llamarl::simulator::hardware::{paper_speedup, BASE_BG, BASE_BT};
use llamarl::simulator::problem::{eval_sync_config, solve_async};
use llamarl::simulator::{GpuSpec, HardwareModel, ModelSpec, LLAMA_MODELS};
use llamarl::util::bench::Table;
use llamarl::util::stats::linfit;

fn main() {
    println!("\n=== Figure 7: efficiency gain vs model scale (log-x) ===\n");
    let mut t = Table::new(&["model", "log10(B)", "paper gain", "sim gain", "ascii"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for m in LLAMA_MODELS {
        let hw = HardwareModel::paper_scale(m);
        let base = hw.baseline_replay_secs();
        let hw8 = HardwareModel {
            fp8_generator: true,
            ..hw
        };
        let asn = solve_async(&hw8.problem());
        let s = base / asn.step_secs;
        let x = (m.params / 1e9).log10();
        xs.push(x);
        ys.push(s);
        t.row(vec![
            m.name.into(),
            format!("{x:.2}"),
            format!("{:.2}x", paper_speedup(m.name)),
            format!("{s:.2}x"),
            "#".repeat((s * 3.0).round() as usize),
        ]);
    }

    // Extrapolation: a hypothetical 1T model on 2048 GPUs. No paper baseline
    // row exists, so anchor by scaling the 405B etas by the FLOPs ratio and
    // evaluate the baseline at its minimum feasible co-located degree.
    let tera = ModelSpec {
        name: "1T*",
        params: 1000e9,
        layers: 160.0,
        d_model: 20480.0,
        gqa_ratio: 8.0,
    };
    let hw1t = HardwareModel {
        model: tera,
        gpu: GpuSpec {
            mem_bytes: 80e9,
            bf16_flops: 989e12,
            hbm_bps: 3.35e12,
        },
        g0: 2048.0,
        b0: 2048.0,
        fp8_generator: false,
        mp_penalty: true,
    };
    let hw405 = HardwareModel::paper_scale(LLAMA_MODELS[2]);
    let scale = tera.params / LLAMA_MODELS[2].params;
    let mut p = hw405.problem();
    p.w0 = 2.0 * tera.params;
    p.wg = 2.0 * tera.params;
    p.a_t = hw1t.act_bytes_per_sample();
    p.k_g = hw1t.kv_bytes_per_seq();
    p.g0 = 2048.0;
    let et = p.eta_t;
    let eg = p.eta_g;
    p.eta_t = Box::new(move |b| et(b) * scale);
    p.eta_g = Box::new(move |b| eg(b) * scale);
    p.sync_straggler = llamarl::simulator::hardware::sync_straggler_factor(tera.params);
    // minimum feasible co-located degree for the baseline
    let m_base = ((5.0 * p.w0 + p.a_t * BASE_BT + p.k_g * BASE_BG) / p.m0).ceil();
    p.m_ref = m_base;
    let base_1t = eval_sync_config(&p, BASE_BT, BASE_BG, m_base);
    // at 1T even fp8 leaves the generator multi-node; the paper's §4.3
    // names fp4 as the next step — quartered weights, ~1.8x faster kernels
    let p8 = {
        let mut q = p;
        q.wg /= 4.0;
        let eg8 = q.eta_g;
        q.eta_g = Box::new(move |b| eg8(b) / 1.8);
        q
    };
    let asn = solve_async(&p8);
    let s1t = base_1t / asn.step_secs;
    let x1t = 3.0;
    t.row(vec![
        "1T*".into(),
        format!("{x1t:.2}"),
        "-".into(),
        format!("{s1t:.2}x"),
        "#".repeat((s1t * 3.0).round() as usize),
    ]);
    t.print();

    xs.push(x1t);
    ys.push(s1t);
    let (_, slope, r2) = linfit(&xs, &ys);
    let slopes: Vec<f64> = xs
        .windows(2)
        .zip(ys.windows(2))
        .map(|(x, y)| (y[1] - y[0]) / (x[1] - x[0]))
        .collect();
    println!(
        "\nlinear fit slope {slope:.2} (r2={r2:.2}); successive slopes {:.2} -> {:.2} -> {:.2}",
        slopes[0], slopes[1], slopes[2]
    );
    println!(
        "Shape checks: gain grows with scale across the paper's range\n\
         (8B -> 405B), matching Figure 7; the 1T point needs fp4 generation\n\
         (paper §4.3) to keep the generator within a node's TP reach."
    );
    assert!(
        ys[..3].windows(2).all(|w| w[1] > w[0]),
        "gain must grow with scale on the paper range: {ys:?}"
    );
}
