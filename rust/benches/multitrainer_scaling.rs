//! Bench: multi-trainer data parallelism + the periodic-asynchrony curve.
//!
//! Two measurements:
//!
//! * **trainer scaling** — the identical pre-filled [`RolloutStore`] is
//!   drained by 1 vs 2 trainer replicas. Each replica owns the static
//!   round-robin slice of the step sequence the runtime uses (step `s`
//!   belongs to replica `(s % n + n - 1) % n`), samples its disjoint
//!   shard-slice via `sample_slice`, burns a fixed per-row optimizer cost,
//!   and publishes through its own registered publisher on a real
//!   [`WeightsBus`] (`register_publisher` / `publish_from`). Measured:
//!   trained-rows/sec per arm; the headline ratio `trainer_scaling_2x`
//!   must clear 1.6x (gated by tools/bench_gate.sh).
//! * **periodic curve** — the DES runs the same config through
//!   `simulate_sync`, `simulate_async`, and `simulate_periodic`: the
//!   period fence must land between the two architectures' wall clocks
//!   (slower than free-running async, faster than sync).
//!
//! Shape checks (acceptance): both arms drain the full row quota, the
//! 2-replica partition is exactly disjoint (each replica trains exactly
//! half, nothing sampled twice), every step published, and the periodic
//! DES point sits between sync and async.
//!
//! Emits `BENCH_multitrainer.json` (stdout line + target/ copy; gated
//! against the committed repo-root baseline by tools/bench_gate.sh).
//!
//! CI smoke: `LLAMARL_BENCH_ROUNDS=3` caps the workload.

use std::hint::black_box;
use std::sync::Arc;
use std::time::{Duration, Instant};

use llamarl::data::{Difficulty, Problem};
use llamarl::dataplane::{RolloutStore, StoreConfig};
use llamarl::ddma::WeightsBus;
use llamarl::rl::{FinishReason, Trajectory};
use llamarl::simulator::{simulate_async, simulate_periodic, simulate_sync, DesConfig};
use llamarl::util::bench::{bench_rounds, fmt_secs};
use llamarl::util::json::Value;

const BATCH: usize = 32;
const SHARDS: usize = 8;
const PARAMS: usize = 8192;

/// A fixed few hundred microseconds of real compute per trained row — the
/// per-row optimizer cost the replicas parallelize.
fn train_row(scratch: &mut [u64]) {
    let mut acc = 0x2545_F491_4F6C_DD1Du64;
    for _ in 0..4 {
        for w in scratch.iter_mut() {
            acc = acc.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(11) ^ *w;
            *w = w.wrapping_add(acc);
        }
    }
    black_box(acc);
}

fn row(group_id: u64) -> Trajectory {
    Trajectory {
        group_id,
        replica: 0,
        n_replicas: 1,
        problem: Problem {
            prompt: "1+1=".into(),
            answer: "2".into(),
            difficulty: Difficulty::Add1,
        },
        prompt_tokens: vec![1, 2],
        response_tokens: vec![3],
        behavior_logp: vec![-0.5],
        gen_version: 0,
        chunks: 1,
        finish: FinishReason::Eos,
        reward: 0.0,
        advantage: 0.0,
    }
}

struct ArmOut {
    wall_secs: f64,
    per_replica: Vec<u64>,
    publishes: u64,
}

/// Drain `steps` optimizer steps of BATCH rows each from a pre-filled
/// store with `n_trainers` data-parallel replicas.
fn run_arm(n_trainers: usize, steps: u64) -> ArmOut {
    let total_rows = steps as usize * BATCH;
    let store = Arc::new(RolloutStore::new(StoreConfig {
        capacity: total_rows,
        max_staleness: None,
        shards: SHARDS,
        ..StoreConfig::default()
    }));
    // sequential group ids spread evenly over the shards (shard = id % n),
    // so each replica's slice holds exactly its share of the rows
    for g in 0..total_rows as u64 {
        store.push_group(vec![row(g)]).expect("prefill fits capacity");
    }
    store.close(); // no producers: replicas drain to their quota
    let bus = Arc::new(WeightsBus::new(vec![0.0f32; PARAMS]));
    let publishers: Vec<usize> = (0..n_trainers)
        .map(|r| if r == 0 { 0 } else { bus.register_publisher() })
        .collect();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (r, publisher) in publishers.into_iter().enumerate() {
        let store = store.clone();
        let bus = bus.clone();
        handles.push(std::thread::spawn(move || {
            let mut scratch = vec![1u64; 32 * 1024];
            let params = vec![0.0f32; PARAMS];
            let n = n_trainers as u64;
            let want = (r as u64 + 1) % n;
            let mut trained = 0u64;
            let mut s = 0u64;
            loop {
                // the runtime's static round-robin step partition
                let c = s + 1;
                let next = c + (want + n - c % n) % n;
                if next > steps {
                    break;
                }
                s = next;
                let mut got = 0usize;
                while got < BATCH {
                    match store.sample_slice(
                        r,
                        n_trainers,
                        BATCH - got,
                        Duration::from_millis(100),
                    ) {
                        Some(rows) if rows.is_empty() => continue,
                        Some(rows) => {
                            for _ in &rows {
                                train_row(&mut scratch);
                            }
                            got += rows.len();
                        }
                        None => break, // slice drained
                    }
                }
                trained += got as u64;
                bus.publish_from(publisher, params.clone());
            }
            trained
        }));
    }
    let per_replica: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    ArmOut {
        wall_secs: t0.elapsed().as_secs_f64(),
        per_replica,
        publishes: bus.publish_count(),
    }
}

fn main() {
    println!("\n=== multi-trainer scaling + periodic-asynchrony curve ===\n");
    let rounds = bench_rounds(12);
    let steps = rounds as u64 * 2; // even: splits exactly across 2 replicas
    let total_rows = steps * BATCH as u64;
    println!("workload: {steps} steps x {BATCH} rows, {SHARDS} store shards\n");

    let one = run_arm(1, steps);
    let two = run_arm(2, steps);

    let rows = |a: &ArmOut| -> u64 { a.per_replica.iter().sum() };
    let one_rps = rows(&one) as f64 / one.wall_secs.max(1e-9);
    let two_rps = rows(&two) as f64 / two.wall_secs.max(1e-9);
    let scaling = two_rps / one_rps.max(1e-9);

    println!(
        "1 trainer:  {} rows in {} ({:.0} rows/s)",
        rows(&one),
        fmt_secs(one.wall_secs),
        one_rps
    );
    println!(
        "2 trainers: {} rows in {} ({:.0} rows/s, {:.2}x) split {:?}",
        rows(&two),
        fmt_secs(two.wall_secs),
        two_rps,
        scaling,
        two.per_replica
    );

    // the DES curve: the period fence must sit between the architectures
    let des = DesConfig {
        steps: 120,
        ..DesConfig::default()
    };
    let d_sync = simulate_sync(&des);
    let d_async = simulate_async(&des);
    let d_per = simulate_periodic(&des, 4);
    println!(
        "\nDES wall clock (120 steps): sync {} > periodic {} >= async {}\n",
        fmt_secs(d_sync.total_secs),
        fmt_secs(d_per.total_secs),
        fmt_secs(d_async.total_secs)
    );

    let rows_complete = rows(&one) == total_rows && rows(&two) == total_rows;
    let partition_disjoint = two.per_replica.len() == 2
        && two.per_replica.iter().all(|&r| r == total_rows / 2);
    let publishes_complete = one.publishes >= steps && two.publishes >= steps;
    let periodic_between = d_async.total_secs <= d_per.total_secs + 1e-9
        && d_per.total_secs < d_sync.total_secs;
    println!(
        "shape checks: both arms drained {total_rows} rows: {}; 2-replica \
         partition exactly disjoint: {}; every step published: {}; periodic \
         between sync and async: {}\n",
        if rows_complete { "PASS" } else { "FAIL" },
        if partition_disjoint { "PASS" } else { "FAIL" },
        if publishes_complete { "PASS" } else { "FAIL" },
        if periodic_between { "PASS" } else { "FAIL" },
    );

    let json = Value::object(vec![
        ("rounds", Value::num(rounds as f64)),
        ("steps", Value::num(steps as f64)),
        ("batch", Value::num(BATCH as f64)),
        ("one_trainer_rows_per_sec", Value::num(one_rps)),
        ("two_trainer_rows_per_sec", Value::num(two_rps)),
        ("trainer_scaling_2x", Value::num(scaling)),
        ("des_sync_secs", Value::num(d_sync.total_secs)),
        ("des_periodic_secs", Value::num(d_per.total_secs)),
        ("des_async_secs", Value::num(d_async.total_secs)),
        ("rows_complete", Value::Bool(rows_complete)),
        ("partition_disjoint", Value::Bool(partition_disjoint)),
        ("publishes_complete", Value::Bool(publishes_complete)),
        ("periodic_between", Value::Bool(periodic_between)),
    ]);
    llamarl::util::bench::emit_summary("BENCH_multitrainer.json", &json);
}
