//! Bench: paper **Table 3** — RL step time, synchronous baseline vs
//! LlamaRL, at 8B/70B/405B paper scale.
//!
//! The calibrated cluster cost model (simulator::hardware) replays (a) the
//! paper's exact configurations and (b) the optimizer's own best
//! configurations for both architectures. Absolute numbers are anchored on
//! the paper's baseline rows (that is the calibration input); the async
//! rows and all speedups are model outputs.

use llamarl::simulator::problem::{solve_async, solve_sync};
use llamarl::simulator::{HardwareModel, LLAMA_MODELS, PAPER_TABLE3};
use llamarl::util::bench::Table;

fn main() {
    println!("\n=== Table 3: RL step time (seconds) — paper vs simulator ===\n");
    let mut t = Table::new(&[
        "model",
        "GPUs",
        "system",
        "paper s/step",
        "sim s/step",
        "sim config (bt,bg,mt,mg,theta)",
    ]);

    for m in LLAMA_MODELS {
        let hw = HardwareModel::paper_scale(m);
        let p = hw.problem();
        let paper_base = PAPER_TABLE3
            .iter()
            .find(|r| r.model == m.name && r.system == "baseline")
            .unwrap();
        // the paper's co-located configuration replayed (calibration anchor)
        t.row(vec![
            m.name.into(),
            format!("{}", hw.g0 as u64),
            "baseline replay".into(),
            format!("{:.1}", paper_base.step_secs),
            format!("{:.1}", hw.baseline_replay_secs()),
            format!(
                "bt={} bg={} m={} (paper cfg)",
                llamarl::simulator::hardware::BASE_BT,
                llamarl::simulator::hardware::BASE_BG,
                paper_base.trainer_mp
            ),
        ]);
        // best sync config our optimizer can find (the co-located memory
        // constraint still couples the phases)
        let sync = solve_sync(&p);
        t.row(vec![
            m.name.into(),
            format!("{}", hw.g0 as u64),
            "baseline optimized".into(),
            "-".into(),
            format!("{:.1}", sync.step_secs),
            format!("bt={} bg={} m={}", sync.bt, sync.bg, sync.m),
        ]);

        // bf16 async
        let asn = solve_async(&p);
        let paper_bf16 = PAPER_TABLE3
            .iter()
            .filter(|r| r.model == m.name && r.system == "llamarl" && !r.fp8_generator)
            .map(|r| r.step_secs)
            .fold(f64::INFINITY, f64::min);
        t.row(vec![
            m.name.into(),
            format!("{}", hw.g0 as u64),
            "LlamaRL bf16".into(),
            format!("{:.1}", paper_bf16),
            format!("{:.1}", asn.step_secs),
            format!(
                "bt={} bg={} mt={} mg={} th={:.2}",
                asn.bt, asn.bg, asn.mt, asn.mg, asn.theta
            ),
        ]);

        // fp8 generator async (the paper's best rows at 70B/405B)
        let hw8 = HardwareModel {
            fp8_generator: true,
            ..hw
        };
        let asn8 = solve_async(&hw8.problem());
        let paper_best = PAPER_TABLE3
            .iter()
            .filter(|r| r.model == m.name && r.system == "llamarl")
            .map(|r| r.step_secs)
            .fold(f64::INFINITY, f64::min);
        t.row(vec![
            m.name.into(),
            format!("{}", hw.g0 as u64),
            "LlamaRL fp8 gen".into(),
            format!("{:.1}", paper_best),
            format!("{:.1}", asn8.step_secs),
            format!(
                "bt={} bg={} mt={} mg={} th={:.2}",
                asn8.bt, asn8.bg, asn8.mt, asn8.mg, asn8.theta
            ),
        ]);
    }
    t.print();

    println!("\n--- headline speedups (paper-config baseline / best async) ---\n");
    let mut s = Table::new(&["model", "paper", "simulated", "sim vs optimized sync"]);
    for m in LLAMA_MODELS {
        let hw = HardwareModel::paper_scale(m);
        let base = hw.baseline_replay_secs();
        let sync = solve_sync(&hw.problem());
        let hw8 = HardwareModel {
            fp8_generator: true,
            ..hw
        };
        let asn8 = solve_async(&hw8.problem());
        s.row(vec![
            m.name.into(),
            format!("{:.2}x", llamarl::simulator::hardware::paper_speedup(m.name)),
            format!("{:.2}x", base / asn8.step_secs),
            format!("{:.2}x", sync.step_secs / asn8.step_secs),
        ]);
    }
    s.print();
    println!(
        "\nShape checks: async wins at every size; speedup grows with model size\n\
         (paper: 2.52x at 8B -> 10.7x at 405B)."
    );
}
