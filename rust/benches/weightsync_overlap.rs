//! Bench: the sharded weight-sync plane — monolithic vs inline-sharded vs
//! background-streamed publishes, across wire encodings (paper §5.2,
//! Table 4).
//!
//! Panel 1 (cluster model): the resharding planner's schedule costed on the
//! calibrated link model for the 8B/70B/405B rows — monolithic broadcast
//! (all bytes over one link) vs the planned per-link max, bf16 vs int8 wire
//! encoding.
//!
//! Panel 2 (real, this testbed): per-arm, at equal parameter count —
//!
//! * **publish blocked** — how long the *trainer* thread is stuck inside
//!   `WeightsBus::publish`. Inline arms pay the whole encode + fan-out;
//!   background arms only the version mint + queue handoff (the tentpole:
//!   acceptance requires >= 5x lower for the executor vs inline).
//! * **gen stall** — how long the *generator* pays at its refresh boundary.
//!   Monolithic: the full-snapshot copy. Sharded: the fenced O(1) swap.
//! * **payload MB** — wire bytes per publish: int8 ~4x under f32; sparse
//!   delta orders of magnitude under it at low update density.
//!
//! Exactness is asserted in-loop: full/delta arms must hand the generator a
//! bit-exact copy of the published snapshot; int8/top-k within their
//! documented bounds.
//!
//! Panel 3 (threads): decode keeps running while a version streams in, and
//! a publish burst shows latest-wins coalescing.
//!
//! Panel 4 (DES): end-to-end effect of overlapping the 70B planned sync
//! cost — and of backgrounding the publish fan-out — on the async timeline.
//!
//! Emits a machine-readable summary: the `BENCH_weightsync.json` line on
//! stdout (also written to target/BENCH_weightsync.json; the committed
//! repo-root baseline is compared by tools/bench_gate.sh).
//!
//! CI smoke: `LLAMARL_BENCH_ROUNDS=3` caps the measured rounds.

use std::sync::Arc;
use std::time::Instant;

use llamarl::ddma::topology::DdmaModel;
use llamarl::ddma::{BusOptions, WeightsBus};
use llamarl::simulator::des::simulate_async;
use llamarl::simulator::{simulate_async_buffered, BufferedDesConfig, DesConfig};
use llamarl::util::bench::{bench_rounds, fmt_secs, Table};
use llamarl::util::json::Value;
use llamarl::util::stats::summarize;
use llamarl::weightsync::{
    even_entries, plan_reshard, run_transfer, Layout, ShardEncoding,
};

fn panel_cluster(model: &DdmaModel) -> (f64, f64) {
    println!("--- panel 1: planner schedule on the calibrated link model ---\n");
    let mut t = Table::new(&[
        "model",
        "links",
        "ops",
        "monolithic",
        "planned bf16",
        "planned int8",
        "paper DDMA",
    ]);
    let rows: [(&str, usize, usize, usize, usize, f64); 3] = [
        ("8B", 8_000_000_000, 128, 8, 32, 0.04),
        ("70B", 70_000_000_000, 128, 8, 80, 1.15),
        ("405B", 405_000_000_000, 512, 8, 126, 2.31),
    ];
    let mut planned_70b = (0.0, 0.0);
    for (name, params, trainer_gpus, tp, layers, paper) in rows {
        let es = even_entries(params, layers);
        let src = Layout::fsdp(params, trainer_gpus);
        let dst = Layout::tp(params, tp, &es).expect("synthetic entries tile");
        let plan = plan_reshard(&src, &dst).expect("plan");
        // monolithic broadcast: every byte crosses one link
        let mono = params as f64 * 2.0 / model.link.ib_bps;
        let bf16 = model.plan_secs(&plan, 2.0);
        let int8 = model.plan_secs(&plan, 1.0);
        if name == "70B" {
            planned_70b = (bf16, int8);
        }
        t.row(vec![
            name.into(),
            plan.n_links().to_string(),
            plan.ops.len().to_string(),
            format!("{mono:.2}s"),
            format!("{bf16:.3}s"),
            format!("{int8:.3}s"),
            format!("{paper:.2}s"),
        ]);
    }
    t.print();
    println!(
        "\nshape check: planned time tracks the busiest LINK (shard-sized),\n\
         not the model: the 405B plan is not ~6x the 70B plan.\n"
    );
    planned_70b
}

struct Arm {
    name: &'static str,
    /// p50 seconds the publisher thread is blocked inside publish()
    publish_blocked_secs: f64,
    /// p50 generator boundary stall per refresh
    stall_secs: f64,
    shard_max_secs: f64,
    payload_mb: f64,
    /// streamed weights identical (bitwise) to the published snapshot
    exact: bool,
    /// realized |err| vs the published snapshot at the final round
    max_abs_err: f32,
}

fn measure_monolithic(p: usize, rounds: usize) -> Arm {
    let bus = WeightsBus::new(vec![0.0; p]);
    let mut stalls = Vec::with_capacity(rounds);
    let mut blocked = Vec::with_capacity(rounds);
    for v in 1..=rounds {
        let data = vec![v as f32; p];
        let t_pub = Instant::now();
        bus.publish(data);
        blocked.push(t_pub.elapsed().as_secs_f64());
        // Generator refresh at the boundary: attach, then materialize the
        // snapshot into generator-local memory — the network pull a cluster
        // generator performs here, made explicit because the in-process Arc
        // would otherwise hide it. (The subsequent device upload is common
        // to every arm and excluded.)
        let t0 = Instant::now();
        let snap = bus.latest();
        let local: Vec<f32> = snap.data.as_ref().clone();
        std::hint::black_box(local[local.len() - 1]);
        stalls.push(t0.elapsed().as_secs_f64());
    }
    Arm {
        name: "monolithic",
        publish_blocked_secs: summarize(&blocked).p50,
        stall_secs: summarize(&stalls).p50,
        shard_max_secs: f64::NAN,
        payload_mb: p as f64 * 4.0 / 1e6,
        exact: true,
        max_abs_err: 0.0,
    }
}

/// Deterministic per-round update: `frac` of the elements move (evenly
/// strided, phase-shifted by the round so the touched set rotates).
fn mutate(data: &mut [f32], round: usize, frac: f64) -> f32 {
    let stride = ((1.0 / frac) as usize).max(1);
    let mut max_update = 0.0f32;
    let mut i = round % stride;
    while i < data.len() {
        let upd = 0.01 + (i % 7) as f32 * 0.001;
        data[i] += upd;
        max_update = max_update.max(upd);
        i += stride;
    }
    max_update
}

/// Clustered per-round update: one contiguous block of `frac` of the
/// elements moves (rotating with the round) — past the sparse break-even,
/// the regime where the zero-run-encoded XOR wire format earns its keep.
fn mutate_clustered(data: &mut [f32], round: usize, frac: f64) -> f32 {
    let len = data.len();
    let span = ((len as f64 * frac) as usize).clamp(1, len);
    let slots = (len - span + 1).max(1);
    let start = (round * 8191) % slots;
    let mut max_update = 0.0f32;
    for i in start..start + span {
        let upd = 0.01 + (i % 7) as f32 * 0.001;
        data[i] += upd;
        max_update = max_update.max(upd);
    }
    max_update
}

/// One sharded arm: `background` routes the fan-out through the streaming
/// executor; `update_frac` is the fraction of weights that move per round
/// (1.0 = dense update — the regime the full/int8 encodings assume; sparse
/// regimes are where delta/top-k earn their keep). Returns the arm, the
/// cumulative documented error bound for lossy encodings, and the mean
/// measured update density (nonzero only for `ShardEncoding::Auto`).
fn measure_sharded(
    name: &'static str,
    p: usize,
    rounds: usize,
    encoding: ShardEncoding,
    background: bool,
    update_frac: f64,
    clustered: bool,
) -> (Arm, f32, f64) {
    let es = even_entries(p, 16);
    let mut opts = BusOptions::new(Layout::fsdp(p, 8), Layout::tp(p, 4, &es).expect("entries"));
    opts.encoding = encoding;
    opts.background = background;
    let bus = WeightsBus::with_options(vec![0.0; p], opts).unwrap();
    let slot = bus.register_generator();
    let mut stalls = Vec::with_capacity(rounds);
    let mut blocked = Vec::with_capacity(rounds);
    let mut cur = vec![0.0f32; p];
    let mut cum_bound = 0.0f32;
    let mut exact = true;
    let mut max_err = 0.0f32;
    for v in 1..=rounds {
        cum_bound += if clustered {
            mutate_clustered(&mut cur, v, update_frac)
        } else {
            mutate(&mut cur, v, update_frac)
        };
        // publisher side: with the executor this returns after the enqueue;
        // inline it returns after the whole encode + fan-out
        let t_pub = Instant::now();
        bus.publish(cur.clone());
        blocked.push(t_pub.elapsed().as_secs_f64());
        // settle the background stream so the boundary swap below measures
        // the swap itself, not stream completion (generators never do this;
        // they just keep decoding)
        bus.flush();
        // generator side: the fenced swap is the entire boundary cost
        let t0 = Instant::now();
        let snap = slot.swap_at_boundary().expect("staging complete after flush");
        std::hint::black_box(snap.version);
        stalls.push(t0.elapsed().as_secs_f64());
        for (a, b) in snap.data.iter().zip(&cur) {
            if a.to_bits() != b.to_bits() {
                exact = false;
                max_err = max_err.max((a - b).abs());
            }
        }
    }
    (
        Arm {
            name,
            publish_blocked_secs: summarize(&blocked).p50,
            stall_secs: summarize(&stalls).p50,
            shard_max_secs: bus.mean_shard_max_secs(),
            payload_mb: bus.bytes_streamed() as f64 / rounds as f64 / 1e6,
            exact,
            max_abs_err: max_err,
        },
        cum_bound,
        bus.mean_update_density(),
    )
}

struct Panel2 {
    arms: Vec<Arm>,
    quant_err: f32,
    quant_bound: f32,
    topk_bound: f32,
    /// mean measured update density of the sparse adaptive arm
    auto_density: f64,
}

fn panel_measured(p: usize, rounds: usize) -> Panel2 {
    println!("--- panel 2: publish blocked + generator stall per arm ({p} params, {rounds} rounds) ---\n");
    let mono = measure_monolithic(p, rounds);
    let (inline_f32, _, _) =
        measure_sharded("inline f32", p, rounds, ShardEncoding::F32, false, 1.0, false);
    let (inline_int8, _, _) =
        measure_sharded("inline int8", p, rounds, ShardEncoding::Int8, false, 1.0, false);
    let (bg_f32, _, _) = measure_sharded("bg f32", p, rounds, ShardEncoding::F32, true, 1.0, false);
    let (bg_delta, _, _) =
        measure_sharded("bg delta (1% upd)", p, rounds, ShardEncoding::Delta, true, 0.01, false);
    let (bg_rle, _, _) = measure_sharded(
        "bg delta (60% clustered, RLE)",
        p,
        rounds,
        ShardEncoding::Delta,
        true,
        0.6,
        true,
    );
    let (bg_topk, topk_bound, _) =
        measure_sharded("bg topk (3% upd)", p, rounds, ShardEncoding::TopK, true, 0.03, false);
    // adaptive per-publish selection: the sparse arm must ride the delta
    // wire, the dense arm must fall back to self-contained full f32
    let (bg_auto, _, auto_density) =
        measure_sharded("bg auto (1% upd)", p, rounds, ShardEncoding::Auto, true, 0.01, false);
    let (bg_auto_dense, _, _) =
        measure_sharded("bg auto (dense)", p, rounds, ShardEncoding::Auto, true, 1.0, false);

    // int8 fidelity on a fresh transfer over the very plan the bus streams
    let es = even_entries(p, 16);
    let plan = plan_reshard(&Layout::fsdp(p, 8), &Layout::tp(p, 4, &es).unwrap()).unwrap();
    let probe: Vec<f32> = (0..p).map(|i| ((i % 977) as f32 * 0.37).sin()).collect();
    let mut out = vec![0.0f32; p];
    let fid = run_transfer(&probe, &mut out, &plan, 1, ShardEncoding::Int8);

    let arms = vec![
        mono,
        inline_f32,
        inline_int8,
        bg_f32,
        bg_delta,
        bg_rle,
        bg_topk,
        bg_auto,
        bg_auto_dense,
    ];
    let mut t = Table::new(&[
        "arm",
        "publish blocked (trainer)",
        "gen stall/refresh",
        "max-shard (parallel model)",
        "payload MB",
        "exact",
    ]);
    for a in &arms {
        t.row(vec![
            a.name.into(),
            fmt_secs(a.publish_blocked_secs),
            fmt_secs(a.stall_secs),
            if a.shard_max_secs.is_nan() {
                "-".into()
            } else {
                fmt_secs(a.shard_max_secs)
            },
            format!("{:.3}", a.payload_mb),
            if a.exact {
                "bit".into()
            } else {
                format!("~{:.1e}", a.max_abs_err)
            },
        ]);
    }
    t.print();
    println!(
        "\nquantized round-trip: max |err| {:.3e} <= bound {:.3e}: {}\n",
        fid.max_abs_err,
        fid.err_bound,
        if fid.max_abs_err <= fid.err_bound {
            "PASS"
        } else {
            "FAIL"
        }
    );
    Panel2 {
        arms,
        quant_err: fid.max_abs_err,
        quant_bound: fid.err_bound,
        topk_bound,
        auto_density,
    }
}

fn panel_threads(p: usize) -> u64 {
    println!("--- panel 3: decode runs while versions stream; bursts coalesce ---\n");
    let es = even_entries(p, 16);
    let mut opts = BusOptions::new(Layout::fsdp(p, 8), Layout::tp(p, 4, &es).unwrap());
    opts.background = true;
    let bus = Arc::new(WeightsBus::with_options(vec![0.0; p], opts).unwrap());
    let slot = bus.register_generator();
    let publisher = {
        let bus = bus.clone();
        std::thread::spawn(move || {
            for v in 1..=8u64 {
                bus.publish(vec![v as f32; p]);
            }
        })
    };
    let mut attaches = 0u64;
    let mut swaps = 0u64;
    loop {
        // "decode": the front version stays attached and complete while the
        // link-group workers stream staging buffers underneath it
        let front = slot.attach();
        std::hint::black_box(front.version);
        attaches += 1;
        if slot.swap_at_boundary().is_some() {
            swaps += 1;
        }
        if bus.version() >= 8 {
            bus.flush();
            while slot.swap_at_boundary().is_some() {
                swaps += 1;
            }
            break;
        }
    }
    publisher.join().unwrap();
    let coalesced = bus.coalesced_publishes();
    println!(
        "generator attached {attaches} times (decoding on version N) while {} \
         publishes streamed in the background; {} fenced swaps, {} versions \
         dropped at slots, {} jobs coalesced in link-group queues \
         (latest-wins)\n",
        bus.publish_count(),
        swaps,
        slot.dropped_versions(),
        coalesced,
    );
    coalesced
}

fn panel_des(planned_70b_bf16: f64) {
    println!("--- panel 4: DES timeline with the 70B planned sync cost ---\n");
    let base = DesConfig {
        steps: 100,
        weight_sync_secs: planned_70b_bf16,
        // inline publish fan-out: the trainer pays the planned stream cost
        publish_block_secs: planned_70b_bf16,
        ..DesConfig::default()
    };
    let blocking = simulate_async(&base);
    let overlapped = simulate_async(&DesConfig {
        sync_overlap: true,
        ..base.clone()
    });
    let background = simulate_async(&DesConfig {
        sync_overlap: true,
        background_publish: true,
        ..base.clone()
    });
    let buffered = simulate_async_buffered(
        &DesConfig {
            sync_overlap: true,
            background_publish: true,
            ..base.clone()
        },
        &BufferedDesConfig::default(),
    );
    let mut t = Table::new(&["architecture", "s/step", "gen idle", "speedup"]);
    for (name, r) in [
        ("async, blocking sync", &blocking),
        ("async, overlapped sync", &overlapped),
        ("async, overlapped + bg publish", &background),
        ("buffered, overlapped + bg publish", &buffered),
    ] {
        t.row(vec![
            name.into(),
            format!("{:.2}", r.step_secs_mean),
            format!("{:.1}%", r.gen_idle_frac * 100.0),
            format!("{:.3}x", blocking.total_secs / r.total_secs),
        ]);
    }
    t.print();
    println!();
}

fn main() {
    println!("\n=== weight sync: inline vs background-streamed, per encoding ===\n");
    let model = DdmaModel::calibrated();
    let (planned_70b_bf16, planned_70b_int8) = panel_cluster(&model);

    let p = 1 << 21; // 2M params, 8 MB f32 — big enough to resolve copies
    let rounds = bench_rounds(20);
    let panel2 = panel_measured(p, rounds);
    let coalesced = panel_threads(p);
    panel_des(planned_70b_bf16);

    let [mono, inline_f32, inline_int8, bg_f32, bg_delta, bg_rle, bg_topk, bg_auto, bg_auto_dense] =
        &panel2.arms[..]
    else {
        unreachable!("panel 2 produces nine arms")
    };
    let mono_stall = mono.stall_secs;
    let overlap_stall = inline_f32.stall_secs;
    let quant_stall = inline_int8.stall_secs;
    let stall_ok = overlap_stall < mono_stall && quant_stall < mono_stall;
    let quant_ok = panel2.quant_err <= panel2.quant_bound;
    let overlap_stall_speedup = mono_stall / overlap_stall.max(1e-12);
    let publish_blocked_speedup =
        inline_f32.publish_blocked_secs / bg_f32.publish_blocked_secs.max(1e-12);
    let blocked_5x = publish_blocked_speedup >= 5.0;
    let delta_exact = bg_f32.exact && bg_delta.exact && bg_rle.exact;
    let topk_ok = bg_topk.max_abs_err <= panel2.topk_bound;
    // a 60% clustered update past the sparse break-even must still beat
    // the full-f32 wire via zero-run encoding, bit-exactly
    let rle_below_full = bg_rle.payload_mb < inline_f32.payload_mb;
    // adaptive encoding: sparse publishes must ride the delta wire (well
    // under half the full payload), dense publishes must fall back to
    // full f32 (within noise of it), both bit-exact
    let auto_adaptive = bg_auto.payload_mb < inline_f32.payload_mb / 2.0
        && bg_auto_dense.payload_mb >= inline_f32.payload_mb * 0.9
        && bg_auto.exact
        && bg_auto_dense.exact;
    println!(
        "shape checks: sharded+overlapped stall strictly below monolithic: {}; \
         quantized round-trip within bound: {}; background publish blocked \
         >=5x below inline ({publish_blocked_speedup:.1}x): {}; delta streams \
         bit-exact (incl. RLE): {}; clustered RLE payload below full ({:.2} \
         vs {:.2} MB): {}; top-k within cumulative bound: {}; auto encoding \
         adapts to density ({:.2} MB sparse / {:.2} MB dense, measured \
         density {:.4}): {}",
        if stall_ok { "PASS" } else { "FAIL" },
        if quant_ok { "PASS" } else { "FAIL" },
        if blocked_5x { "PASS" } else { "FAIL" },
        if delta_exact { "PASS" } else { "FAIL" },
        bg_rle.payload_mb,
        inline_f32.payload_mb,
        if rle_below_full { "PASS" } else { "FAIL" },
        if topk_ok { "PASS" } else { "FAIL" },
        bg_auto.payload_mb,
        bg_auto_dense.payload_mb,
        panel2.auto_density,
        if auto_adaptive { "PASS" } else { "FAIL" },
    );

    let json = Value::object(vec![
        ("params", Value::num(p as f64)),
        ("rounds", Value::num(rounds as f64)),
        ("monolithic_stall_secs", Value::num(mono_stall)),
        ("sharded_overlap_stall_secs", Value::num(overlap_stall)),
        ("quantized_overlap_stall_secs", Value::num(quant_stall)),
        ("monolithic_publish_secs", Value::num(mono.publish_blocked_secs)),
        (
            "inline_publish_blocked_secs",
            Value::num(inline_f32.publish_blocked_secs),
        ),
        (
            "executor_publish_blocked_secs",
            Value::num(bg_f32.publish_blocked_secs),
        ),
        (
            "publish_blocked_speedup",
            Value::num(publish_blocked_speedup),
        ),
        ("overlap_stall_speedup", Value::num(overlap_stall_speedup)),
        ("executor_stall_secs", Value::num(bg_f32.stall_secs)),
        ("quantized_payload_mb", Value::num(inline_int8.payload_mb)),
        ("delta_payload_mb", Value::num(bg_delta.payload_mb)),
        ("rle_delta_payload_mb", Value::num(bg_rle.payload_mb)),
        ("topk_payload_mb", Value::num(bg_topk.payload_mb)),
        ("auto_payload_mb", Value::num(bg_auto.payload_mb)),
        ("auto_dense_payload_mb", Value::num(bg_auto_dense.payload_mb)),
        ("auto_update_density", Value::num(panel2.auto_density)),
        ("full_payload_mb", Value::num(inline_f32.payload_mb)),
        ("quant_max_abs_err", Value::num(panel2.quant_err as f64)),
        ("quant_err_bound", Value::num(panel2.quant_bound as f64)),
        ("topk_max_abs_err", Value::num(bg_topk.max_abs_err as f64)),
        ("topk_err_bound", Value::num(panel2.topk_bound as f64)),
        ("coalesced_publishes", Value::num(coalesced as f64)),
        ("planned_70b_bf16_secs", Value::num(planned_70b_bf16)),
        ("planned_70b_int8_secs", Value::num(planned_70b_int8)),
        ("stall_strictly_lower", Value::Bool(stall_ok)),
        ("quant_within_bound", Value::Bool(quant_ok)),
        ("publish_blocked_5x", Value::Bool(blocked_5x)),
        ("delta_exact", Value::Bool(delta_exact)),
        ("rle_below_full", Value::Bool(rle_below_full)),
        ("topk_within_bound", Value::Bool(topk_ok)),
        ("auto_adaptive", Value::Bool(auto_adaptive)),
    ]);
    llamarl::util::bench::emit_summary("BENCH_weightsync.json", &json);
}
