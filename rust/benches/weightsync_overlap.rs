//! Bench: the sharded weight-sync plane — monolithic vs sharded vs
//! sharded+quantized+overlapped sync (paper §5.2, Table 4).
//!
//! Panel 1 (cluster model): the resharding planner's schedule costed on the
//! calibrated link model for the 8B/70B/405B rows — monolithic broadcast
//! (all bytes over one link) vs the planned per-link max, bf16 vs int8 wire
//! encoding.
//!
//! Panel 2 (real, this testbed): *sync-attributable* generator stall per
//! publish at equal parameter count. What differs between the protocols is
//! WHEN the snapshot gets materialized into generator-local memory — the
//! testbed analogue of the cluster's "pull the new weights over the
//! network". Monolithic: the full-vector copy happens on the generator
//! thread at the refresh boundary (an in-process `Arc` attach hides this
//! cost, so the arm performs the copy explicitly — on a cluster there is
//! no shared memory to hide behind). Sharded+overlapped: the copy streamed
//! into the double-buffered slot off the boundary (on the publisher's
//! clock here, on DMA engines on a cluster), so the boundary pays only the
//! fenced O(1) swap. The device-upload cost downstream of either path is
//! identical in both arms (coordinator::generator::upload_params) and is
//! excluded as a common term. Acceptance: sharded+overlapped boundary
//! stall strictly below monolithic, and the quantized path's round-trip
//! error within `model::int8_error_bound`.
//!
//! Panel 3 (threads): decode keeps running while a version streams in.
//!
//! Panel 4 (DES): end-to-end effect of overlapping the 70B planned sync
//! cost on the async timeline.
//!
//! Emits a machine-readable summary: the `BENCH_weightsync.json` line on
//! stdout (also written to target/BENCH_weightsync.json).

use std::sync::Arc;
use std::time::Instant;

use llamarl::ddma::topology::DdmaModel;
use llamarl::ddma::WeightsBus;
use llamarl::simulator::des::simulate_async;
use llamarl::simulator::{simulate_async_buffered, BufferedDesConfig, DesConfig};
use llamarl::util::bench::{fmt_secs, Table};
use llamarl::util::json::Value;
use llamarl::util::stats::summarize;
use llamarl::weightsync::{even_entries, plan_reshard, run_transfer, Layout, ShardEncoding};

fn panel_cluster(model: &DdmaModel) -> (f64, f64) {
    println!("--- panel 1: planner schedule on the calibrated link model ---\n");
    let mut t = Table::new(&[
        "model",
        "links",
        "ops",
        "monolithic",
        "planned bf16",
        "planned int8",
        "paper DDMA",
    ]);
    let rows: [(&str, usize, usize, usize, usize, f64); 3] = [
        ("8B", 8_000_000_000, 128, 8, 32, 0.04),
        ("70B", 70_000_000_000, 128, 8, 80, 1.15),
        ("405B", 405_000_000_000, 512, 8, 126, 2.31),
    ];
    let mut planned_70b = (0.0, 0.0);
    for (name, params, trainer_gpus, tp, layers, paper) in rows {
        let es = even_entries(params, layers);
        let src = Layout::fsdp(params, trainer_gpus);
        let dst = Layout::tp(params, tp, &es).expect("synthetic entries tile");
        let plan = plan_reshard(&src, &dst).expect("plan");
        // monolithic broadcast: every byte crosses one link
        let mono = params as f64 * 2.0 / model.link.ib_bps;
        let bf16 = model.plan_secs(&plan, 2.0);
        let int8 = model.plan_secs(&plan, 1.0);
        if name == "70B" {
            planned_70b = (bf16, int8);
        }
        t.row(vec![
            name.into(),
            plan.n_links().to_string(),
            plan.ops.len().to_string(),
            format!("{mono:.2}s"),
            format!("{bf16:.3}s"),
            format!("{int8:.3}s"),
            format!("{paper:.2}s"),
        ]);
    }
    t.print();
    println!(
        "\nshape check: planned time tracks the busiest LINK (shard-sized),\n\
         not the model: the 405B plan is not ~6x the 70B plan.\n"
    );
    planned_70b
}

struct Arm {
    name: &'static str,
    publish_secs: f64,
    stall_secs: f64,
    shard_max_secs: f64,
    payload_mb: f64,
}

fn measure_monolithic(p: usize, rounds: usize) -> Arm {
    let bus = WeightsBus::new(vec![0.0; p]);
    let mut stalls = Vec::with_capacity(rounds);
    for v in 1..=rounds {
        let data = vec![v as f32; p];
        bus.publish(data);
        // Generator refresh at the boundary: attach, then materialize the
        // snapshot into generator-local memory — the network pull a cluster
        // generator performs here, made explicit because the in-process Arc
        // would otherwise hide it. (The subsequent device upload is common
        // to every arm and excluded.)
        let t0 = Instant::now();
        let snap = bus.latest();
        let local: Vec<f32> = snap.data.as_ref().clone();
        std::hint::black_box(local[local.len() - 1]);
        stalls.push(t0.elapsed().as_secs_f64());
    }
    Arm {
        name: "monolithic",
        publish_secs: bus.mean_publish_secs(),
        stall_secs: summarize(&stalls).p50,
        shard_max_secs: f64::NAN,
        payload_mb: p as f64 * 4.0 / 1e6,
    }
}

fn measure_sharded(
    name: &'static str,
    p: usize,
    rounds: usize,
    encoding: ShardEncoding,
) -> (Arm, f32, f32) {
    let es = even_entries(p, 16);
    let src = Layout::fsdp(p, 8);
    let dst = Layout::tp(p, 4, &es).expect("entries tile");
    let bus = WeightsBus::with_layouts(vec![0.0; p], src, dst, encoding).unwrap();
    let slot = bus.register_generator();
    let mut stalls = Vec::with_capacity(rounds);
    for v in 1..=rounds {
        let data = vec![v as f32 * 0.01; p];
        // publisher side: encode + stream the plan into the staging buffer
        // (off the generator's critical path once threads are involved)
        bus.publish(data);
        // generator side: the fenced swap is the entire boundary cost
        let t0 = Instant::now();
        let snap = slot.swap_at_boundary().expect("staging complete after publish");
        std::hint::black_box(snap.version);
        stalls.push(t0.elapsed().as_secs_f64());
    }
    // quantization fidelity, measured on a fresh transfer of random-ish data
    // over the very plan the bus streams
    let probe: Vec<f32> = (0..p).map(|i| ((i % 977) as f32 * 0.37).sin()).collect();
    let mut out = vec![0.0f32; p];
    let fid = run_transfer(&probe, &mut out, bus.plan(), 1, encoding);
    (
        Arm {
            name,
            publish_secs: bus.mean_publish_secs(),
            stall_secs: summarize(&stalls).p50,
            shard_max_secs: bus.mean_shard_max_secs(),
            payload_mb: bus.bytes_streamed() as f64 / rounds as f64 / 1e6,
        },
        fid.max_abs_err,
        fid.err_bound,
    )
}

fn panel_measured(p: usize, rounds: usize) -> (Vec<Arm>, f32, f32) {
    println!("--- panel 2: measured generator stall per publish ({p} params) ---\n");
    let mono = measure_monolithic(p, rounds);
    let (sharded, _, _) = measure_sharded("sharded+overlap", p, rounds, ShardEncoding::F32);
    let (quant, err, bound) =
        measure_sharded("sharded+int8+overlap", p, rounds, ShardEncoding::Int8);
    let arms = vec![mono, sharded, quant];
    let mut t = Table::new(&[
        "arm",
        "publish (trainer)",
        "gen stall/publish",
        "max-shard (parallel model)",
        "payload MB",
    ]);
    for a in &arms {
        t.row(vec![
            a.name.into(),
            fmt_secs(a.publish_secs),
            fmt_secs(a.stall_secs),
            if a.shard_max_secs.is_nan() {
                "-".into()
            } else {
                fmt_secs(a.shard_max_secs)
            },
            format!("{:.2}", a.payload_mb),
        ]);
    }
    t.print();
    println!(
        "\nquantized round-trip: max |err| {err:.3e} <= bound {bound:.3e}: {}\n",
        if err <= bound { "PASS" } else { "FAIL" }
    );
    (arms, err, bound)
}

fn panel_threads(p: usize) {
    println!("--- panel 3: decode keeps running while a version streams in ---\n");
    let es = even_entries(p, 16);
    let bus = Arc::new(
        WeightsBus::with_layouts(
            vec![0.0; p],
            Layout::fsdp(p, 8),
            Layout::tp(p, 4, &es).unwrap(),
            ShardEncoding::F32,
        )
        .unwrap(),
    );
    let slot = bus.register_generator();
    let publisher = {
        let bus = bus.clone();
        std::thread::spawn(move || {
            for v in 1..=5u64 {
                bus.publish(vec![v as f32; p]);
            }
        })
    };
    let mut attaches = 0u64;
    let mut swaps = 0u64;
    loop {
        // "decode": the front version stays attached and complete while the
        // publisher streams staging buffers underneath it
        let front = slot.attach();
        std::hint::black_box(front.version);
        attaches += 1;
        if slot.swap_at_boundary().is_some() {
            swaps += 1;
        }
        if bus.version() >= 5 {
            // publisher done: drain whatever is still staged, then stop
            while slot.swap_at_boundary().is_some() {
                swaps += 1;
            }
            break;
        }
    }
    publisher.join().unwrap();
    println!(
        "generator attached {attaches} times (decoding on version N) while {} \
         publishes streamed in; {} fenced swaps, {} versions skipped \
         (latest-wins)\n",
        bus.publish_count(),
        swaps,
        slot.dropped_versions(),
    );
}

fn panel_des(planned_70b_bf16: f64) {
    println!("--- panel 4: DES timeline with the 70B planned sync cost ---\n");
    let base = DesConfig {
        steps: 100,
        weight_sync_secs: planned_70b_bf16,
        ..DesConfig::default()
    };
    let blocking = simulate_async(&base);
    let overlapped = simulate_async(&DesConfig {
        sync_overlap: true,
        ..base.clone()
    });
    let buffered = simulate_async_buffered(
        &DesConfig {
            sync_overlap: true,
            ..base.clone()
        },
        &BufferedDesConfig::default(),
    );
    let mut t = Table::new(&["architecture", "s/step", "gen idle", "speedup"]);
    for (name, r) in [
        ("async, blocking sync", &blocking),
        ("async, overlapped sync", &overlapped),
        ("buffered, overlapped sync", &buffered),
    ] {
        t.row(vec![
            name.into(),
            format!("{:.2}", r.step_secs_mean),
            format!("{:.1}%", r.gen_idle_frac * 100.0),
            format!("{:.3}x", blocking.total_secs / r.total_secs),
        ]);
    }
    t.print();
    println!();
}

fn main() {
    println!("\n=== weight sync: monolithic vs sharded vs quantized+overlapped ===\n");
    let model = DdmaModel::calibrated();
    let (planned_70b_bf16, planned_70b_int8) = panel_cluster(&model);

    let p = 1 << 21; // 2M params, 8 MB f32 — big enough to resolve copies
    let rounds = 20;
    let (arms, quant_err, quant_bound) = panel_measured(p, rounds);
    panel_threads(p);
    panel_des(planned_70b_bf16);

    let mono_stall = arms[0].stall_secs;
    let overlap_stall = arms[1].stall_secs;
    let quant_stall = arms[2].stall_secs;
    let stall_ok = overlap_stall < mono_stall && quant_stall < mono_stall;
    let quant_ok = quant_err <= quant_bound;
    println!(
        "shape checks: sharded+overlapped stall strictly below monolithic: {}; \
         quantized round-trip within bound: {}",
        if stall_ok { "PASS" } else { "FAIL" },
        if quant_ok { "PASS" } else { "FAIL" },
    );

    let json = Value::object(vec![
        ("params", Value::num(p as f64)),
        ("rounds", Value::num(rounds as f64)),
        ("monolithic_stall_secs", Value::num(mono_stall)),
        ("sharded_overlap_stall_secs", Value::num(overlap_stall)),
        ("quantized_overlap_stall_secs", Value::num(quant_stall)),
        ("monolithic_publish_secs", Value::num(arms[0].publish_secs)),
        ("sharded_publish_secs", Value::num(arms[1].publish_secs)),
        ("quantized_payload_mb", Value::num(arms[2].payload_mb)),
        ("quant_max_abs_err", Value::num(quant_err as f64)),
        ("quant_err_bound", Value::num(quant_bound as f64)),
        ("planned_70b_bf16_secs", Value::num(planned_70b_bf16)),
        ("planned_70b_int8_secs", Value::num(planned_70b_int8)),
        ("stall_strictly_lower", Value::Bool(stall_ok)),
        ("quant_within_bound", Value::Bool(quant_ok)),
    ]);
    let line = json.to_string();
    println!("BENCH_weightsync.json {line}");
    // cargo runs benches with CWD = the package dir; the workspace target
    // dir lives one level up unless CARGO_TARGET_DIR overrides it
    let target_dir = std::env::var("CARGO_TARGET_DIR")
        .unwrap_or_else(|_| format!("{}/../target", env!("CARGO_MANIFEST_DIR")));
    let path = format!("{target_dir}/BENCH_weightsync.json");
    if let Err(e) = std::fs::write(&path, &line) {
        eprintln!("warning: could not write {path}: {e}");
    }
}
