//! Bench: paper **Figure 2** — execution-timeline structure of synchronous
//! on-policy vs asynchronous off-policy RL, via the discrete-event
//! simulator: idle-bubble fractions, straggler sensitivity, and the
//! partial-rollout ablation (paper §4.2, Kimi-style).

use llamarl::simulator::des::{simulate_async, simulate_sync};
use llamarl::simulator::DesConfig;
use llamarl::util::bench::Table;

fn main() {
    println!("\n=== Figure 2: timeline bubbles, sync vs async (DES) ===\n");

    // Panel 1: bubble structure across straggler regimes
    let mut t = Table::new(&[
        "gen sigma",
        "sync s/step",
        "async s/step",
        "speedup",
        "sync train idle",
        "async train idle",
        "async lag",
    ]);
    for sigma in [0.2, 0.6, 1.0, 1.4] {
        let cfg = DesConfig {
            steps: 200,
            gen_sigma: sigma,
            ..DesConfig::default()
        };
        let s = simulate_sync(&cfg);
        let a = simulate_async(&cfg);
        t.row(vec![
            format!("{sigma}"),
            format!("{:.2}", s.step_secs_mean),
            format!("{:.2}", a.step_secs_mean),
            format!("{:.2}x", s.total_secs / a.total_secs),
            format!("{:.0}%", s.train_idle_frac * 100.0),
            format!("{:.0}%", a.train_idle_frac * 100.0),
            format!("{:.2}", a.mean_lag_steps),
        ]);
    }
    t.print();

    // Panel 2: partial-rollout ablation under heavy stragglers
    println!("\n--- partial rollouts (cap on per-iteration generation) ---\n");
    let mut pr = Table::new(&["cap (x mean)", "sync s/step", "async s/step", "async speedup vs no-cap"]);
    let heavy = DesConfig {
        steps: 200,
        gen_sigma: 1.2,
        ..DesConfig::default()
    };
    let base_async = simulate_async(&heavy).total_secs;
    for cap in [f64::INFINITY, 4.0, 2.0, 1.5] {
        let cfg = DesConfig {
            partial_rollout_cap: cap,
            ..heavy.clone()
        };
        let s = simulate_sync(&cfg);
        let a = simulate_async(&cfg);
        pr.row(vec![
            if cap.is_finite() {
                format!("{cap}")
            } else {
                "off".into()
            },
            format!("{:.2}", s.step_secs_mean),
            format!("{:.2}", a.step_secs_mean),
            format!("{:.2}x", base_async / a.total_secs),
        ]);
    }
    pr.print();

    // Panel 3: queue depth vs lag trade-off (train-bound regime: the
    // generator runs ahead, so the queue actually fills and staleness
    // becomes visible)
    println!("\n--- queue capacity: throughput vs off-policy lag (train-bound) ---\n");
    let mut q = Table::new(&["queue cap", "async s/step", "mean lag (steps)"]);
    for cap in [1, 2, 4, 8] {
        let cfg = DesConfig {
            steps: 200,
            queue_capacity: cap,
            train_secs: 48.0,
            ..DesConfig::default()
        };
        let a = simulate_async(&cfg);
        q.row(vec![
            cap.to_string(),
            format!("{:.2}", a.step_secs_mean),
            format!("{:.2}", a.mean_lag_steps),
        ]);
    }
    q.print();

    println!(
        "\nShape checks (paper Fig. 2): the sync trainer idles most of each step\n\
         (generation bubble); async removes the bubble at the cost of bounded\n\
         off-policy lag; bubbles worsen with straggler variance; partial\n\
         rollouts claw the straggler tail back."
    );
}
